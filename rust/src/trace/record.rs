//! The persistent trace schema (v1 + v2): one [`TraceMeta`] header,
//! per-job arrival/departure rows, and per-task rows with phase timing.
//!
//! All times are in the run's native unit — virtual seconds for DES
//! traces, *emulated* seconds for sparklite traces (wall measurements are
//! divided by `time_scale` at capture so traces from both sources are
//! directly comparable and replayable).
//!
//! **Schema v2** adds the scenario shape: optional per-worker speeds and
//! the replication factor in the meta header, plus a per-task
//! replica-winner flag — so heterogeneous/redundant runs can be recorded
//! instead of rejected at `trace record`. **Schema v3** adds fault
//! injection: a 1-based attempt counter and a failure-cause tag
//! ([`crate::trace::cause`]) on every task row, so crashed, failed, and
//! speculatively re-executed attempts are all persisted. **Schema v4**
//! adds the dispatch-policy shape: the policy token in the meta header
//! and a per-task policy class on every task row, so SITA / priority /
//! work-stealing runs can be recorded. Capture picks the lowest schema
//! that carries the run (homogeneous non-redundant fault-free FCFS runs
//! stay v1), and v1/v2/v3 files round-trip bit-exactly through both
//! codecs: a v1 trace is written back in the v1 wire format, byte for
//! byte.

use super::cause;
use crate::config::ModelKind;
use crate::emulator::EmulatorResult;
use crate::sim::SimResult;

/// The original scenario-free schema.
pub const SCHEMA_V1: u32 = 1;
/// Scenario-aware schema: meta speeds/replicas + task winner flags.
pub const SCHEMA_V2: u32 = 2;
/// Fault-aware schema: per-task attempt counter + failure-cause tag.
pub const SCHEMA_V3: u32 = 3;
/// Policy-aware schema: meta policy token + per-task policy class.
pub const SCHEMA_V4: u32 = 4;
/// Highest on-disk schema version this build reads and writes (NDJSON
/// and binary carry the same one).
pub const SCHEMA_VERSION: u32 = SCHEMA_V4;

/// Trace header: where the trace came from and under which parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    /// Schema version (see [`SCHEMA_VERSION`]).
    pub schema: u32,
    /// Producing engine: `"sim"` (DES) or `"emulator"` (sparklite).
    pub source: String,
    /// Model token, parseable by [`ModelKind::parse`].
    pub model: String,
    /// Workers / executors l.
    pub servers: u32,
    /// Tasks per job k the run was configured with.
    pub tasks_per_job: u32,
    /// Jobs with `index < warmup` are transient (kept in task rows, but
    /// excluded from `measured_jobs`).
    pub warmup: u32,
    /// RNG seed of the producing run.
    pub seed: u64,
    /// Wall seconds per trace second at capture (1.0 for DES traces).
    pub time_scale: f64,
    /// Inter-arrival distribution spec of the producing run.
    pub interarrival: String,
    /// Task execution-time distribution spec of the producing run.
    pub execution: String,
    /// Per-worker speed multipliers of the producing run (schema ≥ 2;
    /// `None` = homogeneous cluster).
    pub speeds: Option<Vec<f64>>,
    /// First-finish-wins replicas per task (schema ≥ 2; 1 = none).
    pub replicas: u32,
    /// Per-replica launch overhead in seconds (schema ≥ 2; the
    /// replica-launch cost term of the redundancy-aware overhead model;
    /// 0 when not configured).
    pub launch_overhead: f64,
    /// Dispatch-policy token of the producing run (schema ≥ 4;
    /// `"sita"`, `"priority"`, or `"worksteal"`). Empty = plain FCFS.
    pub policy: String,
}

/// One job's arrival/departure row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobRow {
    /// Job index (arrival order, warmup included in the numbering).
    pub index: u32,
    /// Tasks in the job.
    pub tasks: u32,
    /// Arrival time A(n).
    pub arrival: f64,
    /// Departure time D(n) (includes pre-departure overhead).
    pub departure: f64,
    /// First task service start (driver submission for emulator traces).
    pub first_start: f64,
    /// Total workload Σ execution times (no overhead).
    pub workload: f64,
    /// Total task-service overhead Σ O_i.
    pub task_overhead: f64,
    /// Measured pre-departure overhead (merge + bookkeeping).
    pub pre_departure_overhead: f64,
    /// Server time burned by cancelled replicas (redundancy scenarios).
    pub redundant_work: f64,
}

impl JobRow {
    /// Sojourn time T(n) = D(n) − A(n).
    pub fn sojourn(&self) -> f64 {
        self.departure - self.arrival
    }

    /// Schedule delay: arrival until the first task starts service.
    pub fn schedule_delay(&self) -> f64 {
        (self.first_start - self.arrival).max(0.0)
    }
}

/// One task's row with phase timing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskRow {
    /// Owning job index.
    pub job: u32,
    /// Task index within the job.
    pub task: u32,
    /// Server (executor) that ran it.
    pub server: u32,
    /// Service start instant.
    pub start: f64,
    /// Service end instant (occupancy release).
    pub end: f64,
    /// Task-service overhead portion of `[start, end]`.
    pub overhead: f64,
    /// Replica-winner flag (schema ≥ 2): true for the replica whose
    /// result counted; false rows measure cancelled redundant work.
    /// Always true in v1 traces.
    pub winner: bool,
    /// Attempt number, 1-based (schema ≥ 3). Always 1 in v1/v2 traces.
    pub attempt: u32,
    /// Failure-cause tag (schema ≥ 3; see [`crate::trace::cause`]).
    /// Always [`cause::NONE`] in v1/v2 traces.
    pub cause: u8,
    /// Dispatch-policy class of the task (schema ≥ 4): the SITA size
    /// interval or priority class that routed it. Always 0 in v1–v3
    /// traces and under FCFS / work stealing.
    pub class: u32,
}

impl TaskRow {
    /// Observed execution duration (occupancy minus overhead).
    pub fn service(&self) -> f64 {
        (self.end - self.start - self.overhead).max(0.0)
    }

    /// Server occupancy Q_i (execution + overhead).
    pub fn occupancy(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }
}

/// A complete captured trace: header + job rows + task rows.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Header describing the producing run.
    pub meta: TraceMeta,
    /// Per-job rows, sorted by `index`.
    pub jobs: Vec<JobRow>,
    /// Per-task rows, sorted by `(job, task, server)`.
    pub tasks: Vec<TaskRow>,
}

impl Trace {
    /// Canonicalize row order (capture and every read path go through
    /// this so a write → read round trip is exactly the identity, and so
    /// consumers can rely on sorted rows even for hand-authored NDJSON).
    pub(crate) fn normalize(mut self) -> Self {
        self.jobs.sort_by_key(|j| j.index);
        self.tasks.sort_by_key(|t| (t.job, t.task, t.server, t.attempt));
        self
    }

    /// Capture a trace from a finished DES run. The run must have used
    /// `RunOptions { record_jobs: true, trace: true, .. }`; job rows cover
    /// the measured (post-warmup) jobs, task rows cover every job.
    pub fn from_sim(res: &SimResult) -> Result<Self, String> {
        if res.jobs.is_empty() {
            return Err("simulation kept no job records (RunOptions.record_jobs)".into());
        }
        if res.trace.events().is_empty() {
            return Err("simulation kept no task trace (RunOptions.trace)".into());
        }
        let cfg = &res.config;
        // Scenario runs need the v2 fields; scenario-free runs stay v1
        // so their files remain byte-identical to pre-v2 captures.
        let speeds = match &cfg.workers {
            Some(w) => Some(w.resolve(cfg.servers)?),
            None => None,
        };
        let replicas = cfg.replicas() as u32;
        // Fault-injected runs need the v3 attempt/cause columns.
        let faulty = cfg.faults.map(|f| f.is_active()).unwrap_or(false);
        // Policy runs need the v4 meta token / class column.
        let policy = match &cfg.policy {
            Some(p) if p.is_active() => p.kind.to_string(),
            _ => String::new(),
        };
        let schema = if !policy.is_empty() {
            SCHEMA_V4
        } else if faulty {
            SCHEMA_V3
        } else if speeds.is_some() || replicas > 1 {
            SCHEMA_V2
        } else {
            SCHEMA_V1
        };
        let meta = TraceMeta {
            schema,
            source: "sim".into(),
            model: cfg.model.to_string(),
            servers: cfg.servers as u32,
            tasks_per_job: cfg.tasks_per_job as u32,
            warmup: cfg.warmup as u32,
            seed: cfg.seed,
            time_scale: 1.0,
            interarrival: cfg.arrival.interarrival.clone(),
            execution: cfg.service.execution.clone(),
            speeds,
            replicas,
            // Ignored by the simulator at r = 1 (and rejected by config
            // validation there); the clamp keeps a hand-built r = 1
            // config from producing an unreadable v1 trace.
            launch_overhead: if replicas > 1 { cfg.launch_overhead() } else { 0.0 },
            policy,
        };
        let k = cfg.tasks_per_job as u32;
        let jobs = res
            .jobs
            .iter()
            .map(|r| JobRow {
                index: r.index as u32,
                tasks: k,
                arrival: r.arrival,
                departure: r.departure,
                first_start: r.first_start,
                workload: r.workload,
                task_overhead: r.task_overhead,
                pre_departure_overhead: r.pre_departure_overhead,
                redundant_work: r.redundant_work,
            })
            .collect();
        let tasks = res
            .trace
            .events()
            .iter()
            .map(|e| TaskRow {
                job: e.job,
                task: e.task,
                server: e.server,
                start: e.start,
                end: e.end,
                overhead: e.overhead,
                winner: e.winner,
                attempt: e.attempt,
                cause: e.cause,
                class: e.class,
            })
            .collect();
        Ok(Trace { meta, jobs, tasks }.normalize())
    }

    /// Capture a trace from a finished sparklite run. Wall measurements
    /// are converted to emulated seconds (`/ time_scale`); the executor
    /// finish timestamp anchors each task row, so `start` is derived as
    /// `finished − occupancy`.
    pub fn from_emulator(res: &EmulatorResult) -> Result<Self, String> {
        if res.listener.jobs.is_empty() {
            return Err("emulator run recorded no jobs".into());
        }
        let cfg = &res.config;
        let scale = cfg.time_scale;
        // Pinned executor speeds are real measured behavior: record them
        // in the v2 meta so replay/calibration see the skewed cluster.
        let speeds = match &cfg.workers {
            Some(w) => Some(w.resolve(cfg.executors)?),
            None => None,
        };
        let schema = if speeds.is_some() { SCHEMA_V2 } else { SCHEMA_V1 };
        let meta = TraceMeta {
            schema,
            source: "emulator".into(),
            model: cfg.mode.to_string(),
            servers: cfg.executors as u32,
            tasks_per_job: cfg.tasks_per_job as u32,
            warmup: cfg.warmup as u32,
            seed: cfg.seed,
            time_scale: scale,
            interarrival: cfg.interarrival.clone(),
            execution: cfg.execution.clone(),
            speeds,
            replicas: 1,
            launch_overhead: 0.0,
            policy: String::new(),
        };
        let jobs = res
            .listener
            .jobs
            .iter()
            .map(|j| JobRow {
                index: j.job_id as u32,
                tasks: j.tasks,
                arrival: j.arrival,
                departure: j.departure,
                first_start: j.submitted,
                workload: j.total_execution,
                task_overhead: j.total_task_overhead,
                pre_departure_overhead: (j.departure - j.last_result).max(0.0),
                redundant_work: 0.0,
            })
            .collect();
        let tasks = res
            .listener
            .tasks
            .iter()
            .map(|t| TaskRow {
                job: t.job_id as u32,
                task: t.task_id,
                server: t.executor_id,
                start: (t.finished - t.occupancy) / scale,
                end: t.finished / scale,
                overhead: t.overhead() / scale,
                winner: true,
                attempt: 1,
                cause: cause::NONE,
                class: 0,
            })
            .collect();
        Ok(Trace { meta, jobs, tasks }.normalize())
    }

    /// The recorded model kind.
    pub fn model(&self) -> Result<ModelKind, String> {
        ModelKind::parse(&self.meta.model)
    }

    /// Post-warmup job rows (the measurement window).
    pub fn measured_jobs(&self) -> impl Iterator<Item = &JobRow> {
        let warmup = self.meta.warmup;
        self.jobs.iter().filter(move |j| j.index >= warmup)
    }

    /// Measured-job sojourn times, in index order.
    pub fn sojourns(&self) -> Vec<f64> {
        self.measured_jobs().map(|j| j.sojourn()).collect()
    }

    /// Winning-replica service (execution) durations, in row order — the
    /// sample bank behind `empirical:<trace-file>` distributions. Rows of
    /// cancelled replicas (schema v2 redundancy) carry clipped, partial
    /// timings and are excluded; v1 traces are all winners, so this is
    /// every row there.
    pub fn task_services(&self) -> Vec<f64> {
        self.tasks.iter().filter(|t| t.winner).map(|t| t.service()).collect()
    }

    /// Winning-replica overhead samples, in row order (the calibration
    /// pipeline's `O_i` measurements; cancelled replicas excluded as in
    /// [`Trace::task_services`]).
    pub fn task_overheads(&self) -> Vec<f64> {
        self.tasks.iter().filter(|t| t.winner).map(|t| t.overhead).collect()
    }

    /// Busy fraction per server over `[t0, t1]` — the Fig.-1/2 idle-time
    /// statistic, computed from the persisted task rows (the file-based
    /// analog of [`crate::trace::TraceLog::utilization`]).
    pub fn utilization(&self, t0: f64, t1: f64) -> Vec<f64> {
        assert!(t1 > t0);
        let mut busy = vec![0.0; self.meta.servers as usize];
        for t in &self.tasks {
            let s = t.start.max(t0);
            let e = t.end.min(t1);
            if e > s {
                busy[t.server as usize] += e - s;
            }
        }
        busy.iter().map(|b| b / (t1 - t0)).collect()
    }

    /// Measured `(k, pre-departure)` samples for the Sec.-2.6 regression.
    pub fn pre_departure_samples(&self) -> Vec<(f64, f64)> {
        self.measured_jobs()
            .map(|j| (j.tasks as f64, j.pre_departure_overhead))
            .collect()
    }

    /// Structural validation: schema version, sane meta, finite rows.
    pub fn validate(&self) -> Result<(), String> {
        if !(SCHEMA_V1..=SCHEMA_VERSION).contains(&self.meta.schema) {
            return Err(format!(
                "unsupported trace schema {} (this build reads 1..={SCHEMA_VERSION})",
                self.meta.schema
            ));
        }
        if self.meta.servers == 0 {
            return Err("trace meta: servers must be >= 1".into());
        }
        ModelKind::parse(&self.meta.model)?;
        if self.meta.schema == SCHEMA_V1 {
            // v1 carries no scenario shape; a v1 trace claiming one would
            // silently drop it on the v1 wire format.
            if self.meta.speeds.is_some()
                || self.meta.replicas != 1
                || self.meta.launch_overhead != 0.0
            {
                return Err(
                    "schema v1 cannot carry worker speeds, replicas, or launch \
                     overhead; use schema 2"
                        .into(),
                );
            }
            if self.tasks.iter().any(|t| !t.winner) {
                return Err(
                    "schema v1 cannot carry replica-winner flags; use schema 2".into()
                );
            }
        }
        if self.meta.schema < SCHEMA_V3 {
            // v1/v2 carry no attempt/cause columns; a lower-schema trace
            // claiming them would silently drop fault data on the wire.
            if self.tasks.iter().any(|t| t.attempt != 1 || t.cause != cause::NONE) {
                return Err(
                    "schema v1/v2 cannot carry retry attempts or failure causes; \
                     use schema 3"
                        .into(),
                );
            }
        }
        if self.meta.schema < SCHEMA_V4 {
            // v1–v3 carry no policy columns; a lower-schema trace
            // claiming them would silently drop policy data on the wire.
            if !self.meta.policy.is_empty() || self.tasks.iter().any(|t| t.class != 0) {
                return Err(
                    "schema v1-v3 cannot carry a dispatch policy or task classes; \
                     use schema 4"
                        .into(),
                );
            }
        }
        if let Some(speeds) = &self.meta.speeds {
            if speeds.len() != self.meta.servers as usize {
                return Err(format!(
                    "trace meta: {} speeds for {} servers",
                    speeds.len(),
                    self.meta.servers
                ));
            }
            for &s in speeds {
                if !(s > 0.0 && s.is_finite()) {
                    return Err(format!(
                        "trace meta: speeds must be positive and finite, got {s}"
                    ));
                }
            }
        }
        if self.meta.replicas == 0 || self.meta.replicas > self.meta.servers {
            return Err(format!(
                "trace meta: replicas ({}) must be in 1..=servers ({})",
                self.meta.replicas, self.meta.servers
            ));
        }
        if !(self.meta.launch_overhead >= 0.0 && self.meta.launch_overhead.is_finite()) {
            return Err(format!(
                "trace meta: launch overhead must be finite and >= 0, got {}",
                self.meta.launch_overhead
            ));
        }
        for j in &self.jobs {
            if !(j.arrival.is_finite() && j.departure.is_finite()) {
                return Err(format!("job {}: non-finite arrival/departure", j.index));
            }
            if j.departure < j.arrival {
                return Err(format!("job {}: departure before arrival", j.index));
            }
        }
        for t in &self.tasks {
            if !(t.start.is_finite() && t.end.is_finite() && t.overhead.is_finite()) {
                return Err(format!("task ({}, {}): non-finite timing", t.job, t.task));
            }
            if t.end < t.start {
                return Err(format!("task ({}, {}): end before start", t.job, t.task));
            }
            if t.server >= self.meta.servers {
                return Err(format!(
                    "task ({}, {}): server {} out of range (trace has {} servers)",
                    t.job, t.task, t.server, self.meta.servers
                ));
            }
            if t.attempt == 0 {
                return Err(format!(
                    "task ({}, {}): attempt numbers are 1-based",
                    t.job, t.task
                ));
            }
            if t.cause > cause::MAX {
                return Err(format!(
                    "task ({}, {}): unknown failure cause {} (defined: 0..={})",
                    t.job,
                    t.task,
                    t.cause,
                    cause::MAX
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, SimulationConfig};
    use crate::sim::{self, RunOptions};

    fn captured() -> Trace {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 2,
            tasks_per_job: 4,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 50,
            warmup: 5,
            seed: 3,
            overhead: Some(crate::config::OverheadConfig::paper()),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        Trace::from_sim(&res).unwrap()
    }

    #[test]
    fn capture_from_sim_has_expected_shape() {
        let tr = captured();
        // Scenario-free runs stay on the v1 wire format.
        assert_eq!(tr.meta.schema, SCHEMA_V1);
        assert_eq!(tr.meta.speeds, None);
        assert_eq!(tr.meta.replicas, 1);
        assert_eq!(tr.meta.source, "sim");
        assert_eq!(tr.jobs.len(), 50);
        // Task rows include warmup jobs (55 × 4 tasks).
        assert_eq!(tr.tasks.len(), 55 * 4);
        assert_eq!(tr.measured_jobs().count(), 50);
        assert_eq!(tr.model().unwrap(), ModelKind::ForkJoinSingleQueue);
        tr.validate().unwrap();
        // Overhead was on: every task row carries at least the constant.
        assert!(tr.task_overheads().iter().all(|&o| o >= 2.6e-3 - 1e-12));
        // Service excludes the overhead portion.
        for t in &tr.tasks {
            assert!(t.service() <= t.occupancy());
        }
    }

    #[test]
    fn capture_requires_recorded_jobs_and_trace() {
        let cfg = SimulationConfig {
            servers: 2,
            tasks_per_job: 4,
            jobs: 10,
            warmup: 0,
            ..SimulationConfig::default()
        };
        let res = sim::run(&cfg, RunOptions::default()).unwrap();
        assert!(Trace::from_sim(&res).is_err());
    }

    #[test]
    fn schema_mismatch_rejected() {
        let mut tr = captured();
        tr.meta.schema = 99;
        assert!(tr.validate().is_err());
    }

    /// Scenario runs capture the v2 shape: speeds + replicas in the
    /// meta, one winner per logical task, losers flagged.
    #[test]
    fn scenario_capture_is_v2_with_winners() {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 4,
            tasks_per_job: 8,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.3".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 40,
            warmup: 4,
            seed: 5,
            overhead: None,
            workers: Some(crate::config::WorkersConfig::Speeds(vec![1.5, 1.5, 0.5, 0.5])),
            redundancy: Some(crate::config::RedundancyConfig {
                replicas: 2,
                launch_overhead: 2e-3,
            }),
            faults: None,
            policy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        let tr = Trace::from_sim(&res).unwrap();
        tr.validate().unwrap();
        assert_eq!(tr.meta.schema, SCHEMA_V2);
        assert_eq!(tr.meta.speeds, Some(vec![1.5, 1.5, 0.5, 0.5]));
        assert_eq!(tr.meta.replicas, 2);
        assert_eq!(tr.meta.launch_overhead, 2e-3);
        // Every logical (job, task) has exactly one winner row.
        let mut winners = std::collections::BTreeMap::new();
        for t in &tr.tasks {
            *winners.entry((t.job, t.task)).or_insert(0u32) += u32::from(t.winner);
        }
        assert!(winners.values().all(|&w| w == 1), "one winner per task");
        assert!(tr.tasks.iter().any(|t| !t.winner), "losers must be recorded");
        // The sample banks exclude cancelled replicas.
        assert_eq!(tr.task_services().len(), 44 * 8);
        // A v1 claim over this payload is rejected.
        let mut bad = tr.clone();
        bad.meta.schema = SCHEMA_V1;
        assert!(bad.validate().is_err());
    }

    /// Fault-injected runs capture schema v3: retried attempts appear as
    /// extra rows with attempt counters and cause tags; lower schemas
    /// reject the payload.
    #[test]
    fn fault_capture_is_v3_with_attempts() {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 2,
            tasks_per_job: 4,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.2".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 40,
            warmup: 4,
            seed: 11,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: Some(crate::config::FaultsConfig {
                task_fail_p: 0.3,
                max_retries: 2,
                backoff_base: 0.01,
                ..Default::default()
            }),
            policy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        let tr = Trace::from_sim(&res).unwrap();
        tr.validate().unwrap();
        assert_eq!(tr.meta.schema, SCHEMA_V3);
        assert!(
            tr.tasks.iter().any(|t| t.cause == cause::FAILED),
            "p=0.3 over 176 tasks must record failed attempts"
        );
        assert!(
            tr.tasks.iter().any(|t| t.attempt > 1),
            "failed tasks must record their retry attempts"
        );
        // Every logical task ends in exactly one winner.
        let mut winners = std::collections::BTreeMap::new();
        for t in &tr.tasks {
            *winners.entry((t.job, t.task)).or_insert(0u32) += u32::from(t.winner);
        }
        assert!(winners.values().all(|&w| w == 1), "one winner per task");
        // The sample banks keep only counted attempts.
        assert_eq!(tr.task_services().len(), 44 * 4);
        // v1/v2 claims over this payload are rejected.
        for schema in [SCHEMA_V1, SCHEMA_V2] {
            let mut bad = tr.clone();
            bad.meta.schema = schema;
            assert!(bad.validate().is_err(), "schema {schema} must reject attempts");
        }
        // Malformed v3 rows are rejected.
        let mut bad = tr.clone();
        bad.tasks[0].attempt = 0;
        assert!(bad.validate().is_err());
        let mut bad = tr.clone();
        bad.tasks[0].cause = cause::MAX + 1;
        assert!(bad.validate().is_err());
    }

    /// Policy runs capture schema v4: the policy token lands in the meta
    /// and task rows carry the routing class; lower schemas reject the
    /// payload.
    #[test]
    fn policy_capture_is_v4_with_classes() {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 4,
            tasks_per_job: 4,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.2".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 40,
            warmup: 4,
            seed: 7,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: Some(crate::config::PolicyConfig {
                kind: crate::config::PolicyKind::Sita,
                sita_boundaries: vec![0.5],
                ..Default::default()
            }),
        };
        let res = sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        let tr = Trace::from_sim(&res).unwrap();
        tr.validate().unwrap();
        assert_eq!(tr.meta.schema, SCHEMA_V4);
        assert_eq!(tr.meta.policy, "sita");
        // A boundary near the service distribution's bulk: over 176
        // tasks both size intervals are hit.
        assert!(tr.tasks.iter().any(|t| t.class == 0));
        assert!(tr.tasks.iter().any(|t| t.class == 1));
        // v1–v3 claims over this payload are rejected.
        for schema in [SCHEMA_V1, SCHEMA_V2, SCHEMA_V3] {
            let mut bad = tr.clone();
            bad.meta.schema = schema;
            assert!(bad.validate().is_err(), "schema {schema} must reject classes");
        }
    }

    /// Speeds arity/positivity and replica range are validated.
    #[test]
    fn scenario_meta_validation() {
        let mut tr = captured();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.speeds = Some(vec![1.0]); // 2 servers
        assert!(tr.validate().is_err());
        let mut tr = captured();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.speeds = Some(vec![1.0, 0.0]);
        assert!(tr.validate().is_err());
        let mut tr = captured();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.replicas = 3; // 2 servers
        assert!(tr.validate().is_err());
        let mut tr = captured();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.launch_overhead = -1.0;
        assert!(tr.validate().is_err());
        // v1 cannot claim a launch cost either.
        let mut tr = captured();
        tr.meta.launch_overhead = 0.5;
        assert!(tr.validate().is_err());
    }

    #[test]
    fn malformed_rows_rejected() {
        let mut tr = captured();
        tr.tasks[0].server = 99; // captured trace has 2 servers
        assert!(tr.validate().is_err());

        let mut tr = captured();
        tr.jobs[0].departure = tr.jobs[0].arrival - 1.0;
        assert!(tr.validate().is_err());

        let mut tr = captured();
        tr.tasks[0].end = tr.tasks[0].start - 1.0;
        assert!(tr.validate().is_err());
    }

    #[test]
    fn utilization_matches_live_trace_log() {
        let tr = captured();
        let live = {
            let cfg = SimulationConfig {
                model: ModelKind::ForkJoinSingleQueue,
                servers: 2,
                tasks_per_job: 4,
                arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
                service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
                jobs: 50,
                warmup: 5,
                seed: 3,
                overhead: Some(crate::config::OverheadConfig::paper()),
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            };
            let res = sim::run(
                &cfg,
                RunOptions { record_jobs: true, trace: true, ..Default::default() },
            )
            .unwrap();
            res.trace.utilization(2, 0.0, 10.0)
        };
        let persisted = tr.utilization(0.0, 10.0);
        for (a, b) in live.iter().zip(&persisted) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }
}
