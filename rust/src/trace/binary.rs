//! Compact binary trace codec — fixed-width little-endian rows behind a
//! magic header, reusing the emulator's wire [`Encoder`]/[`Decoder`].
//! Floats travel as raw IEEE-754 bits, so the round trip is bitwise by
//! construction; a job row costs 64 bytes and a task row 36 bytes versus
//! ~200 bytes of NDJSON, which is what makes million-task traces
//! practical to keep.
//!
//! Schema versioning: the fifth magic byte carries the trace's schema
//! (1–4) and must agree with the `schema` field that follows. A v1
//! trace is written in the v1 wire layout byte-for-byte; schema 2
//! appends the scenario shape (meta `replicas` + optional speeds, task
//! `winner` bytes); schema 3 appends the fault shape (task `attempt` +
//! `cause`); schema 4 appends the policy shape (meta `policy` string,
//! task `class`), each leaving the lower layouts untouched.

use super::record::{
    JobRow, TaskRow, Trace, TraceMeta, SCHEMA_V1, SCHEMA_V3, SCHEMA_V4, SCHEMA_VERSION,
};
use crate::emulator::{Decoder, Encoder};

/// File magic prefix shared by every schema version.
pub const MAGIC_PREFIX: [u8; 4] = [b'T', b'T', b'R', b'C'];

/// The v1 file magic: `TTRC` + schema byte 1 (kept for compatibility
/// with pre-v2 callers; v2 files carry schema byte 2).
pub const MAGIC: [u8; 5] = [b'T', b'T', b'R', b'C', SCHEMA_V1 as u8];

/// Serialize a trace to the binary format.
pub fn to_binary(trace: &Trace) -> Vec<u8> {
    let mut e = Encoder::new();
    let m = &trace.meta;
    let v1 = m.schema == SCHEMA_V1;
    let v3 = m.schema >= SCHEMA_V3;
    let v4 = m.schema >= SCHEMA_V4;
    for b in MAGIC_PREFIX {
        e.u8(b);
    }
    e.u8(m.schema as u8);
    e.u32(m.schema);
    e.str(&m.source);
    e.str(&m.model);
    e.u32(m.servers);
    e.u32(m.tasks_per_job);
    e.u32(m.warmup);
    e.u64(m.seed);
    e.f64(m.time_scale);
    e.str(&m.interarrival);
    e.str(&m.execution);
    if !v1 {
        e.u32(m.replicas);
        e.f64(m.launch_overhead);
        match &m.speeds {
            Some(speeds) => {
                e.u8(1);
                e.f64_seq(speeds);
            }
            None => e.u8(0),
        }
    }
    if v4 {
        e.str(&m.policy);
    }
    e.u32(trace.jobs.len() as u32);
    for j in &trace.jobs {
        e.u32(j.index);
        e.u32(j.tasks);
        e.f64(j.arrival);
        e.f64(j.departure);
        e.f64(j.first_start);
        e.f64(j.workload);
        e.f64(j.task_overhead);
        e.f64(j.pre_departure_overhead);
        e.f64(j.redundant_work);
    }
    e.u32(trace.tasks.len() as u32);
    for t in &trace.tasks {
        e.u32(t.job);
        e.u32(t.task);
        e.u32(t.server);
        e.f64(t.start);
        e.f64(t.end);
        e.f64(t.overhead);
        if !v1 {
            e.u8(u8::from(t.winner));
        }
        if v3 {
            e.u32(t.attempt);
            e.u8(t.cause);
        }
        if v4 {
            e.u32(t.class);
        }
    }
    e.finish()
}

/// Parse a trace from binary bytes.
pub fn from_binary(bytes: &[u8]) -> Result<Trace, String> {
    if !is_binary(bytes) {
        return Err("not a binary tiny-tasks trace (bad magic)".into());
    }
    let magic_schema = bytes[4] as u32;
    let mut d = Decoder::new(&bytes[MAGIC.len()..]);
    let err = |e: crate::emulator::DecodeError| format!("binary trace: {e}");
    let schema = d.u32().map_err(err)?;
    if schema != magic_schema {
        return Err(format!(
            "binary trace: magic version byte {magic_schema} disagrees with schema {schema}"
        ));
    }
    let v1 = schema == SCHEMA_V1;
    let v3 = schema >= SCHEMA_V3;
    let v4 = schema >= SCHEMA_V4;
    let mut meta = TraceMeta {
        schema,
        source: d.str().map_err(err)?,
        model: d.str().map_err(err)?,
        servers: d.u32().map_err(err)?,
        tasks_per_job: d.u32().map_err(err)?,
        warmup: d.u32().map_err(err)?,
        seed: d.u64().map_err(err)?,
        time_scale: d.f64().map_err(err)?,
        interarrival: d.str().map_err(err)?,
        execution: d.str().map_err(err)?,
        speeds: None,
        replicas: 1,
        launch_overhead: 0.0,
        policy: String::new(),
    };
    if !v1 {
        meta.replicas = d.u32().map_err(err)?;
        meta.launch_overhead = d.f64().map_err(err)?;
        if d.u8().map_err(err)? != 0 {
            meta.speeds = Some(d.f64_seq().map_err(err)?);
        }
    }
    if v4 {
        meta.policy = d.str().map_err(err)?;
    }
    let n_jobs = d.u32().map_err(err)? as usize;
    let mut jobs = Vec::with_capacity(n_jobs.min(1 << 24));
    for _ in 0..n_jobs {
        jobs.push(JobRow {
            index: d.u32().map_err(err)?,
            tasks: d.u32().map_err(err)?,
            arrival: d.f64().map_err(err)?,
            departure: d.f64().map_err(err)?,
            first_start: d.f64().map_err(err)?,
            workload: d.f64().map_err(err)?,
            task_overhead: d.f64().map_err(err)?,
            pre_departure_overhead: d.f64().map_err(err)?,
            redundant_work: d.f64().map_err(err)?,
        });
    }
    let n_tasks = d.u32().map_err(err)? as usize;
    let mut tasks = Vec::with_capacity(n_tasks.min(1 << 24));
    for _ in 0..n_tasks {
        tasks.push(TaskRow {
            job: d.u32().map_err(err)?,
            task: d.u32().map_err(err)?,
            server: d.u32().map_err(err)?,
            start: d.f64().map_err(err)?,
            end: d.f64().map_err(err)?,
            overhead: d.f64().map_err(err)?,
            winner: if v1 { true } else { d.u8().map_err(err)? != 0 },
            attempt: if v3 { d.u32().map_err(err)? } else { 1 },
            cause: if v3 { d.u8().map_err(err)? } else { 0 },
            class: if v4 { d.u32().map_err(err)? } else { 0 },
        });
    }
    if d.remaining() != 0 {
        return Err(format!("binary trace: {} trailing bytes", d.remaining()));
    }
    Ok(Trace { meta, jobs, tasks })
}

/// True when `bytes` starts with a binary trace magic of a schema this
/// build reads.
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= 5
        && bytes[..4] == MAGIC_PREFIX
        && (SCHEMA_V1..=SCHEMA_VERSION).contains(&(bytes[4] as u32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::SCHEMA_V2;

    fn tiny_trace() -> Trace {
        Trace {
            meta: TraceMeta {
                schema: SCHEMA_V1,
                source: "emulator".into(),
                model: "split-merge".into(),
                servers: 4,
                tasks_per_job: 16,
                warmup: 2,
                seed: 0xDEAD_BEEF_CAFE_F00D,
                time_scale: 0.01,
                interarrival: "exp:0.5".into(),
                execution: "exp:4.0".into(),
                speeds: None,
                replicas: 1,
                launch_overhead: 0.0,
                policy: String::new(),
            },
            jobs: vec![JobRow {
                index: 2,
                tasks: 16,
                arrival: 1.5,
                departure: 3.75,
                first_start: 1.5000000001,
                workload: 4.0,
                task_overhead: 0.05,
                pre_departure_overhead: 0.02,
                redundant_work: 0.0,
            }],
            tasks: vec![TaskRow {
                job: 2,
                task: 0,
                server: 3,
                start: 1.5,
                end: 1.75,
                overhead: 0.003,
                winner: true,
                attempt: 1,
                cause: 0,
                class: 0,
            }],
        }
    }

    fn tiny_trace_v2() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.speeds = Some(vec![1.5, 0.5, 1.0, 1.0]);
        tr.meta.replicas = 2;
        tr.meta.launch_overhead = 5e-3;
        tr.tasks.push(TaskRow {
            job: 2,
            task: 0,
            server: 1,
            start: 1.5,
            end: 1.75,
            overhead: 0.001,
            winner: false,
            attempt: 1,
            cause: 0,
            class: 0,
        });
        tr
    }

    fn tiny_trace_v3() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V3;
        tr.tasks[0].attempt = 2;
        tr.tasks[0].cause = crate::trace::cause::SPECULATION;
        tr.tasks.push(TaskRow {
            job: 2,
            task: 0,
            server: 1,
            start: 1.0,
            end: 1.25,
            overhead: 0.001,
            winner: false,
            attempt: 1,
            cause: crate::trace::cause::CRASHED,
            class: 0,
        });
        tr
    }

    fn tiny_trace_v4() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V4;
        tr.meta.policy = "priority".into();
        tr.tasks[0].class = 1;
        tr
    }

    #[test]
    fn binary_round_trip_is_exact() {
        let tr = tiny_trace();
        let bytes = to_binary(&tr);
        assert!(is_binary(&bytes));
        let back = from_binary(&bytes).unwrap();
        assert_eq!(tr, back);
        // Re-encoding the parsed trace gives byte-identical output.
        assert_eq!(bytes, to_binary(&back));
    }

    /// The v1 wire layout is unchanged: no scenario bytes at all, and the
    /// historical 5-byte magic still matches.
    #[test]
    fn v1_layout_is_stable() {
        let bytes = to_binary(&tiny_trace());
        assert_eq!(&bytes[..MAGIC.len()], &MAGIC);
        // Header + meta (4 + 1 + 4 + (4+8) + (4+11) + 4·3 + 8 + 8 +
        // (4+7) + (4+7)) + job count/row (4 + 64) + task count/row
        // (4 + 36): fully fixed for this payload.
        let expect = 5 + 4 + 12 + 15 + 12 + 16 + 11 + 11 + 4 + 64 + 4 + 36;
        assert_eq!(bytes.len(), expect);
    }

    #[test]
    fn v2_round_trip_is_exact() {
        let tr = tiny_trace_v2();
        let bytes = to_binary(&tr);
        assert!(is_binary(&bytes));
        assert_eq!(bytes[4], 2);
        let back = from_binary(&bytes).unwrap();
        assert_eq!(tr, back);
        assert_eq!(bytes, to_binary(&back));
        // v2 without speeds (redundancy only) also round-trips.
        let mut tr = tiny_trace_v2();
        tr.meta.speeds = None;
        let back = from_binary(&to_binary(&tr)).unwrap();
        assert_eq!(tr, back);
    }

    #[test]
    fn v3_round_trip_is_exact() {
        let tr = tiny_trace_v3();
        let bytes = to_binary(&tr);
        assert!(is_binary(&bytes));
        assert_eq!(bytes[4], 3);
        let back = from_binary(&bytes).unwrap();
        assert_eq!(tr, back);
        assert_eq!(bytes, to_binary(&back));
    }

    #[test]
    fn v4_round_trip_is_exact() {
        let tr = tiny_trace_v4();
        let bytes = to_binary(&tr);
        assert!(is_binary(&bytes));
        assert_eq!(bytes[4], 4);
        let back = from_binary(&bytes).unwrap();
        assert_eq!(tr, back);
        assert_eq!(bytes, to_binary(&back));
    }

    #[test]
    fn truncation_and_garbage_are_errors() {
        for tr in [tiny_trace(), tiny_trace_v2(), tiny_trace_v3(), tiny_trace_v4()] {
            let bytes = to_binary(&tr);
            assert!(from_binary(&bytes[..bytes.len() - 3]).is_err());
            let mut trailing = bytes.clone();
            trailing.push(0);
            assert!(from_binary(&trailing).is_err());
        }
        assert!(from_binary(b"not a trace").is_err());
    }

    #[test]
    fn wrong_schema_byte_rejected() {
        let mut bytes = to_binary(&tiny_trace());
        bytes[4] = 5; // future magic version: not a readable trace
        assert!(from_binary(&bytes).is_err());
        let mut bytes = to_binary(&tiny_trace());
        bytes[4] = 2; // readable version, but disagrees with the body
        assert!(from_binary(&bytes).is_err());
    }
}
