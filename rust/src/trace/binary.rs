//! Compact binary trace codec — fixed-width little-endian rows behind a
//! magic header, reusing the emulator's wire [`Encoder`]/[`Decoder`].
//! Floats travel as raw IEEE-754 bits, so the round trip is bitwise by
//! construction; a job row costs 64 bytes and a task row 36 bytes versus
//! ~200 bytes of NDJSON, which is what makes million-task traces
//! practical to keep.

use super::record::{JobRow, TaskRow, Trace, TraceMeta, SCHEMA_VERSION};
use crate::emulator::{Decoder, Encoder};

/// File magic: `TTRC` + the schema version byte (derived from
/// [`SCHEMA_VERSION`] so the two cannot drift when the schema is bumped).
pub const MAGIC: [u8; 5] = [b'T', b'T', b'R', b'C', SCHEMA_VERSION as u8];

/// Serialize a trace to the binary format.
pub fn to_binary(trace: &Trace) -> Vec<u8> {
    let mut e = Encoder::new();
    for b in MAGIC {
        e.u8(b);
    }
    let m = &trace.meta;
    e.u32(m.schema);
    e.str(&m.source);
    e.str(&m.model);
    e.u32(m.servers);
    e.u32(m.tasks_per_job);
    e.u32(m.warmup);
    e.u64(m.seed);
    e.f64(m.time_scale);
    e.str(&m.interarrival);
    e.str(&m.execution);
    e.u32(trace.jobs.len() as u32);
    for j in &trace.jobs {
        e.u32(j.index);
        e.u32(j.tasks);
        e.f64(j.arrival);
        e.f64(j.departure);
        e.f64(j.first_start);
        e.f64(j.workload);
        e.f64(j.task_overhead);
        e.f64(j.pre_departure_overhead);
        e.f64(j.redundant_work);
    }
    e.u32(trace.tasks.len() as u32);
    for t in &trace.tasks {
        e.u32(t.job);
        e.u32(t.task);
        e.u32(t.server);
        e.f64(t.start);
        e.f64(t.end);
        e.f64(t.overhead);
    }
    e.finish()
}

/// Parse a trace from binary bytes.
pub fn from_binary(bytes: &[u8]) -> Result<Trace, String> {
    if !is_binary(bytes) {
        return Err("not a binary tiny-tasks trace (bad magic)".into());
    }
    let mut d = Decoder::new(&bytes[MAGIC.len()..]);
    let err = |e: crate::emulator::DecodeError| format!("binary trace: {e}");
    let schema = d.u32().map_err(err)?;
    if schema != SCHEMA_VERSION {
        return Err(format!(
            "unsupported trace schema {schema} (this build reads {SCHEMA_VERSION})"
        ));
    }
    let meta = TraceMeta {
        schema,
        source: d.str().map_err(err)?,
        model: d.str().map_err(err)?,
        servers: d.u32().map_err(err)?,
        tasks_per_job: d.u32().map_err(err)?,
        warmup: d.u32().map_err(err)?,
        seed: d.u64().map_err(err)?,
        time_scale: d.f64().map_err(err)?,
        interarrival: d.str().map_err(err)?,
        execution: d.str().map_err(err)?,
    };
    let n_jobs = d.u32().map_err(err)? as usize;
    let mut jobs = Vec::with_capacity(n_jobs.min(1 << 24));
    for _ in 0..n_jobs {
        jobs.push(JobRow {
            index: d.u32().map_err(err)?,
            tasks: d.u32().map_err(err)?,
            arrival: d.f64().map_err(err)?,
            departure: d.f64().map_err(err)?,
            first_start: d.f64().map_err(err)?,
            workload: d.f64().map_err(err)?,
            task_overhead: d.f64().map_err(err)?,
            pre_departure_overhead: d.f64().map_err(err)?,
            redundant_work: d.f64().map_err(err)?,
        });
    }
    let n_tasks = d.u32().map_err(err)? as usize;
    let mut tasks = Vec::with_capacity(n_tasks.min(1 << 24));
    for _ in 0..n_tasks {
        tasks.push(TaskRow {
            job: d.u32().map_err(err)?,
            task: d.u32().map_err(err)?,
            server: d.u32().map_err(err)?,
            start: d.f64().map_err(err)?,
            end: d.f64().map_err(err)?,
            overhead: d.f64().map_err(err)?,
        });
    }
    if d.remaining() != 0 {
        return Err(format!("binary trace: {} trailing bytes", d.remaining()));
    }
    Ok(Trace { meta, jobs, tasks })
}

/// True when `bytes` starts with the binary trace magic.
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] == MAGIC
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Trace {
        Trace {
            meta: TraceMeta {
                schema: SCHEMA_VERSION,
                source: "emulator".into(),
                model: "split-merge".into(),
                servers: 4,
                tasks_per_job: 16,
                warmup: 2,
                seed: 0xDEAD_BEEF_CAFE_F00D,
                time_scale: 0.01,
                interarrival: "exp:0.5".into(),
                execution: "exp:4.0".into(),
            },
            jobs: vec![JobRow {
                index: 2,
                tasks: 16,
                arrival: 1.5,
                departure: 3.75,
                first_start: 1.5000000001,
                workload: 4.0,
                task_overhead: 0.05,
                pre_departure_overhead: 0.02,
                redundant_work: 0.0,
            }],
            tasks: vec![TaskRow {
                job: 2,
                task: 0,
                server: 3,
                start: 1.5,
                end: 1.75,
                overhead: 0.003,
            }],
        }
    }

    #[test]
    fn binary_round_trip_is_exact() {
        let tr = tiny_trace();
        let bytes = to_binary(&tr);
        assert!(is_binary(&bytes));
        let back = from_binary(&bytes).unwrap();
        assert_eq!(tr, back);
        // Re-encoding the parsed trace gives byte-identical output.
        assert_eq!(bytes, to_binary(&back));
    }

    #[test]
    fn truncation_and_garbage_are_errors() {
        let bytes = to_binary(&tiny_trace());
        assert!(from_binary(&bytes[..bytes.len() - 3]).is_err());
        assert!(from_binary(b"not a trace").is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(from_binary(&trailing).is_err());
    }

    #[test]
    fn wrong_schema_byte_rejected() {
        let mut bytes = to_binary(&tiny_trace());
        bytes[4] = 2; // future magic version
        assert!(from_binary(&bytes).is_err());
    }
}
