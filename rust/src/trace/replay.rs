//! Trace-driven replay: feed a recorded trace's job arrivals and task
//! service times back through any of the four DES models.
//!
//! The replay workload is deterministic — the inter-arrival and execution
//! "distributions" are scripted sequences that consume no randomness — so
//! replaying the same trace twice is bitwise identical. An optional
//! overhead model resamples fresh `O_i` draws from the workload's seeded
//! RNG on top of the recorded (overhead-free) service times, which is
//! exactly the Sec.-2.6 validation loop: record → fit → replay → compare
//! sojourn distributions.

use super::record::{JobRow, Trace};
use crate::config::{ModelKind, OverheadConfig};
use crate::dist::{Dist, Distribution};
use crate::sim::models::{
    ForkJoinPerServer, ForkJoinSingleQueue, IdealPartition, Model, SplitMerge,
};
use crate::sim::{JobRecord, OverheadModel, TraceLog, Workload};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Options for a replay run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplayOptions {
    /// Model to drive; `None` replays through the recorded model.
    pub model: Option<ModelKind>,
    /// Worker count; `None` uses the recorded server count.
    pub servers: Option<usize>,
    /// Overhead model resampled on top of the recorded service times
    /// (`None` = replay the pure task sizes).
    pub overhead: Option<OverheadConfig>,
    /// Enforce in-order departures in the single-queue fork-join model.
    pub in_order_departures: bool,
    /// Seed for the overhead resampling stream.
    pub seed: u64,
}

/// Outcome of a replay run.
#[derive(Clone, Debug)]
pub struct Replayed {
    /// Model the trace was replayed through.
    pub model: ModelKind,
    /// Worker count used.
    pub servers: usize,
    /// Tasks per job consumed from the trace.
    pub tasks_per_job: usize,
    /// Per-job records in arrival order, one per recorded measured job.
    pub jobs: Vec<JobRecord>,
}

impl Replayed {
    /// Replayed sojourn times, in job order.
    pub fn sojourns(&self) -> Vec<f64> {
        self.jobs.iter().map(|j| j.sojourn()).collect()
    }
}

/// Scripted "distribution" replaying a fixed sample sequence; consumes no
/// randomness (like `Deterministic`), so the shared RNG stream is left to
/// the overhead model alone.
#[derive(Debug)]
struct ReplaySequence {
    values: Vec<f64>,
    next: AtomicUsize,
}

impl ReplaySequence {
    fn new(values: Vec<f64>) -> Self {
        Self { values, next: AtomicUsize::new(0) }
    }
}

impl Distribution for ReplaySequence {
    fn sample(&self, _rng: &mut dyn FnMut() -> f64) -> f64 {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        // Clamp at the end: models never over-draw on a well-formed
        // trace, and a stuck last value beats a panic in release runs.
        self.values[i.min(self.values.len() - 1)]
    }
    fn mean(&self) -> f64 {
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }
    fn variance(&self) -> f64 {
        let m = self.mean();
        self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / self.values.len() as f64
    }
    fn label(&self) -> String {
        format!("Replay(n={})", self.values.len())
    }
}

/// The replica row currently winning one logical (job, task) during the
/// task-row scan.
struct Winning {
    job: u32,
    task: u32,
    end: f64,
    service: f64,
    winner: bool,
}

impl Winning {
    /// Replica resolution: a flagged winner (schema v2) beats any
    /// unflagged row; among equal flags the earliest finisher wins (the
    /// pre-v2 heuristic for foreign traces, ties broken by row order).
    fn beaten_by(&self, t: &crate::trace::TaskRow) -> bool {
        (t.winner && !self.winner) || (t.winner == self.winner && t.end < self.end)
    }
}

/// Commit the current (job, task) winner's service time to its job's
/// sequence; warmup jobs' task rows are skipped.
fn flush_winner(
    cur: &mut Option<Winning>,
    services: &mut [Vec<f64>],
    jobs: &[&JobRow],
    warmup: u32,
) -> Result<(), String> {
    if let Some(w) = cur.take() {
        if w.job >= warmup {
            let ji = jobs
                .binary_search_by_key(&w.job, |j| j.index)
                .map_err(|_| format!("task row for unknown job {}", w.job))?;
            if services[ji].len() != w.task as usize {
                return Err(format!(
                    "job {}: task rows are not contiguous at task {}",
                    w.job, w.task
                ));
            }
            services[ji].push(w.service);
        }
    }
    Ok(())
}

/// Replay `trace`'s measured jobs through a model.
///
/// Task sizes come from the task rows; arrivals come from the job rows.
/// Every measured job must carry the same task count. Redundant traces
/// (schema v2) carry one row per replica: the recorded winner flag picks
/// the replica whose service time drives the replay. Fault-injected
/// traces (schema v3) likewise carry one row per attempt — failed,
/// crashed, and cancelled-speculation rows are all flagged non-winners,
/// so only the succeeding attempt's service time is replayed. Foreign
/// traces without flags fall back to the earliest-finishing row, ties
/// broken deterministically by row order — an approximation, since a
/// winner is then indistinguishable from a replica cancelled at the same
/// instant.
pub fn replay(trace: &Trace, opts: &ReplayOptions) -> Result<Replayed, String> {
    trace.validate()?;
    let model_kind = match opts.model {
        Some(m) => m,
        None => trace.model()?,
    };
    let servers = opts.servers.unwrap_or(trace.meta.servers as usize);
    if servers == 0 {
        return Err("replay needs at least one server".into());
    }

    // Measured jobs in arrival order.
    let jobs: Vec<_> = trace.measured_jobs().collect();
    if jobs.is_empty() {
        return Err("trace has no measured jobs to replay".into());
    }

    // Winning task rows per (job, task): rows are sorted, so scan and
    // resolve replicas of the same logical task — by the recorded winner
    // flag when the trace carries one (schema v2), by earliest finish
    // otherwise.
    let warmup = trace.meta.warmup;
    let mut services: Vec<Vec<f64>> = vec![Vec::new(); jobs.len()];
    let mut cur: Option<Winning> = None;
    for t in &trace.tasks {
        match &mut cur {
            Some(w) if w.job == t.job && w.task == t.task => {
                if w.beaten_by(t) {
                    w.end = t.end;
                    w.service = t.service();
                    w.winner = t.winner;
                }
            }
            _ => {
                flush_winner(&mut cur, &mut services, &jobs, warmup)?;
                cur = Some(Winning {
                    job: t.job,
                    task: t.task,
                    end: t.end,
                    service: t.service(),
                    winner: t.winner,
                });
            }
        }
    }
    flush_winner(&mut cur, &mut services, &jobs, warmup)?;

    let k = services[0].len();
    if k == 0 {
        return Err("trace has no task rows for its measured jobs".into());
    }
    for (j, s) in jobs.iter().zip(&services) {
        if s.len() != k {
            return Err(format!(
                "job {} has {} recorded tasks but job {} has {k}; replay needs a \
                 uniform task count",
                j.index,
                s.len(),
                jobs[0].index
            ));
        }
    }
    if model_kind == ModelKind::ForkJoinPerServer && k != servers {
        return Err(format!(
            "per-server fork-join replay requires k = l (trace has k={k}, l={servers})"
        ));
    }
    if model_kind != ModelKind::Ideal && k < servers {
        return Err(format!(
            "tiny-tasks replay requires k >= l (trace has k={k}, l={servers})"
        ));
    }

    // Inter-arrival gaps reproduce the recorded arrival instants (up to
    // float re-accumulation, far below any distributional tolerance).
    let mut gaps = Vec::with_capacity(jobs.len());
    let mut prev = 0.0;
    for j in &jobs {
        if j.arrival < prev {
            return Err(format!("job {}: arrivals are not monotone", j.index));
        }
        gaps.push(j.arrival - prev);
        prev = j.arrival;
    }
    let execs: Vec<f64> = services.iter().flatten().copied().collect();

    let mut workload = Workload::new(
        Dist::custom(Box::new(ReplaySequence::new(gaps))),
        Dist::custom(Box::new(ReplaySequence::new(execs))),
        opts.seed,
    );
    let overhead = OverheadModel::from_option(opts.overhead);
    let mut model: Box<dyn Model> = match model_kind {
        ModelKind::SplitMerge => Box::new(SplitMerge::new(servers, k)),
        ModelKind::ForkJoinSingleQueue => Box::new(
            ForkJoinSingleQueue::new(servers, k)
                .with_in_order_departures(opts.in_order_departures),
        ),
        ModelKind::ForkJoinPerServer => Box::new(ForkJoinPerServer::new(servers)),
        ModelKind::Ideal => Box::new(IdealPartition::new(servers, k)),
    };
    let mut tr = TraceLog::disabled();
    let mut out = Vec::with_capacity(jobs.len());
    for n in 0..jobs.len() {
        let arrival = workload.next_arrival();
        out.push(model.advance(n, arrival, &mut workload, &overhead, &mut tr));
    }
    Ok(Replayed { model: model_kind, servers, tasks_per_job: k, jobs: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimulationConfig;
    use crate::sim::{self, RunOptions};

    fn record(model: ModelKind, overhead: bool, warmup: usize) -> Trace {
        let cfg = SimulationConfig {
            model,
            servers: 3,
            tasks_per_job: if model == ModelKind::ForkJoinPerServer { 3 } else { 6 },
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.3".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 400,
            warmup,
            seed: 11,
            overhead: overhead.then(crate::config::OverheadConfig::paper),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        Trace::from_sim(&res).unwrap()
    }

    /// Replaying an overhead-free trace through its own model reproduces
    /// the recorded sojourns (up to float re-accumulation of arrivals).
    /// Recorded with warmup = 0 so the replay's empty initial system
    /// matches the recorded one job for job.
    #[test]
    fn replay_reproduces_recorded_sojourns() {
        for model in [
            ModelKind::SplitMerge,
            ModelKind::ForkJoinSingleQueue,
            ModelKind::ForkJoinPerServer,
            ModelKind::Ideal,
        ] {
            let tr = record(model, false, 0);
            let rep = replay(&tr, &ReplayOptions::default()).unwrap();
            assert_eq!(rep.model, model);
            let recorded = tr.sojourns();
            assert_eq!(rep.jobs.len(), recorded.len(), "{model}");
            for (got, want) in rep.sojourns().iter().zip(&recorded) {
                assert!(
                    (got - want).abs() < 1e-6,
                    "{model}: replayed {got} vs recorded {want}"
                );
            }
        }
    }

    /// Replay is bitwise deterministic across invocations.
    #[test]
    fn replay_is_deterministic() {
        let tr = record(ModelKind::ForkJoinSingleQueue, true, 40);
        let opts = ReplayOptions {
            overhead: Some(crate::config::OverheadConfig::paper()),
            seed: 7,
            ..Default::default()
        };
        let a = replay(&tr, &opts).unwrap();
        let b = replay(&tr, &opts).unwrap();
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.departure.to_bits(), y.departure.to_bits());
            assert_eq!(x.workload.to_bits(), y.workload.to_bits());
        }
    }

    /// Cross-model replay: the same recorded workload is legal input for
    /// a different model, and split-merge blocking dominates fork-join.
    #[test]
    fn cross_model_replay_orders_models() {
        let tr = record(ModelKind::ForkJoinSingleQueue, false, 40);
        let fj = replay(&tr, &ReplayOptions::default()).unwrap();
        let sm = replay(
            &tr,
            &ReplayOptions { model: Some(ModelKind::SplitMerge), ..Default::default() },
        )
        .unwrap();
        let mean = |r: &Replayed| {
            r.jobs.iter().map(|j| j.sojourn()).sum::<f64>() / r.jobs.len() as f64
        };
        assert!(mean(&sm) >= mean(&fj), "SM {} !>= FJ {}", mean(&sm), mean(&fj));
    }

    /// Overhead resampling on replay strictly increases sojourns.
    #[test]
    fn replay_with_overhead_increases_sojourn() {
        let tr = record(ModelKind::ForkJoinSingleQueue, false, 40);
        let clean = replay(&tr, &ReplayOptions::default()).unwrap();
        let dirty = replay(
            &tr,
            &ReplayOptions {
                overhead: Some(crate::config::OverheadConfig::paper()),
                seed: 3,
                ..Default::default()
            },
        )
        .unwrap();
        let mean = |r: &Replayed| {
            r.jobs.iter().map(|j| j.sojourn()).sum::<f64>() / r.jobs.len() as f64
        };
        assert!(mean(&dirty) > mean(&clean));
    }

    #[test]
    fn fjps_replay_requires_k_equals_l() {
        let tr = record(ModelKind::ForkJoinSingleQueue, false, 40); // k=6, l=3
        let err = replay(
            &tr,
            &ReplayOptions {
                model: Some(ModelKind::ForkJoinPerServer),
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(err.contains("k = l"), "{err}");
    }
}
