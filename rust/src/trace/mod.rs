//! Trace capture & replay subsystem.
//!
//! The paper fit its four-parameter overhead model from *recorded Spark
//! task traces* (Sec. 2.6); this module gives the reproduction the same
//! persistent substrate. A [`Trace`] is a versioned record of one run —
//! per-job arrival/departure rows plus per-task rows with phase timing
//! (schedule delay, service, task overhead, pre-departure) — captured
//! from either DES engine or the sparklite emulator through the
//! [`TraceLog`] hook, and stored in two interchangeable codecs:
//!
//! * **NDJSON** ([`to_ndjson`]/[`from_ndjson`]) — one flat JSON object
//!   per line, greppable and pandas/jq-friendly;
//! * **binary** ([`to_binary`]/[`from_binary`]) — fixed-width rows behind
//!   a magic header, ~5× smaller, for million-task traces.
//!
//! Both round-trip bit-exactly (floats travel as shortest round-trip
//! text or raw IEEE-754 bits; `rust/tests/trace_roundtrip.rs` enforces
//! it). Schema v2 adds the scenario shape — per-worker speeds and the
//! replication factor in the meta, replica-winner flags on task rows —
//! so heterogeneous/redundant runs record instead of being rejected;
//! schema v3 adds the fault shape — a 1-based attempt counter and a
//! failure-cause tag on task rows — so fault-injected runs record every
//! retry, crash, and speculative copy; schema v4 adds the dispatch
//! policy — the policy token in the meta and a routing class on task
//! rows — so SITA/priority/work-stealing runs record too. Scenario-,
//! fault- and policy-free captures stay on the v1 wire format
//! byte-for-byte.
//! On top of the format sit the consumers:
//!
//! * [`replay`] — feed a recorded trace's arrivals and task sizes back
//!   through any of the four models (trace-driven simulation);
//! * [`crate::dist::Empirical`] — `empirical:<trace-file>` samples task
//!   sizes from a recorded trace instead of a parametric law;
//! * [`crate::coordinator::calibrate::calibrate_from_trace`] — the
//!   Sec.-2.6 moment-fit + PP-refine pipeline against a file instead of
//!   a live emulator.

mod binary;
mod log;
mod ndjson;
mod record;
mod replay;

pub use self::log::{cause, TraceEvent, TraceLog};
pub use binary::{from_binary, is_binary, to_binary, MAGIC, MAGIC_PREFIX};
pub use ndjson::{from_ndjson, to_ndjson};
pub use record::{
    JobRow, TaskRow, Trace, TraceMeta, SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, SCHEMA_V4,
    SCHEMA_VERSION,
};
pub use replay::{replay, ReplayOptions, Replayed};

use std::path::Path;

/// On-disk trace encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    /// One flat JSON object per line.
    Ndjson,
    /// Compact fixed-width binary rows.
    Binary,
}

impl TraceFormat {
    /// Parse a CLI token (`ndjson` | `bin`/`binary`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "ndjson" | "json" => Ok(Self::Ndjson),
            "bin" | "binary" => Ok(Self::Binary),
            _ => Err(format!("unknown trace format {s:?} (ndjson|bin)")),
        }
    }

    /// Infer from a file extension: `.bin`/`.tbin` → binary, else NDJSON.
    pub fn from_path<P: AsRef<Path>>(path: P) -> Self {
        match path.as_ref().extension().and_then(|e| e.to_str()) {
            Some("bin") | Some("tbin") => Self::Binary,
            _ => Self::Ndjson,
        }
    }
}

impl Trace {
    /// Serialize in the given format.
    pub fn to_bytes(&self, format: TraceFormat) -> Vec<u8> {
        match format {
            TraceFormat::Ndjson => to_ndjson(self).into_bytes(),
            TraceFormat::Binary => to_binary(self),
        }
    }

    /// Parse from bytes, sniffing the format (binary magic vs text).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, String> {
        let trace = if is_binary(bytes) {
            from_binary(bytes)?
        } else {
            let text = std::str::from_utf8(bytes)
                .map_err(|_| "trace is neither binary (bad magic) nor UTF-8 text")?;
            from_ndjson(text)?
        };
        trace.validate()?;
        // Externally-authored NDJSON may arrive in any row order; every
        // read path hands consumers canonical (sorted) rows.
        Ok(trace.normalize())
    }

    /// Write to a file; `format` of `None` is inferred from the extension.
    pub fn write_file<P: AsRef<Path>>(
        &self,
        path: P,
        format: Option<TraceFormat>,
    ) -> Result<(), String> {
        let path = path.as_ref();
        let format = format.unwrap_or_else(|| TraceFormat::from_path(path));
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("create {}: {e}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_bytes(format))
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    /// Read from a file, sniffing the format.
    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Self, String> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_bytes(&bytes).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_inference() {
        assert_eq!(TraceFormat::from_path("a/trace.bin"), TraceFormat::Binary);
        assert_eq!(TraceFormat::from_path("a/trace.tbin"), TraceFormat::Binary);
        assert_eq!(TraceFormat::from_path("a/trace.ndjson"), TraceFormat::Ndjson);
        assert_eq!(TraceFormat::from_path("trace"), TraceFormat::Ndjson);
        assert_eq!(TraceFormat::parse("bin").unwrap(), TraceFormat::Binary);
        assert!(TraceFormat::parse("csv").is_err());
    }

    #[test]
    fn bytes_round_trip_both_formats() {
        let cfg = crate::config::SimulationConfig {
            servers: 2,
            tasks_per_job: 4,
            jobs: 20,
            warmup: 2,
            ..Default::default()
        };
        let res = crate::sim::run(
            &cfg,
            crate::sim::RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        let tr = Trace::from_sim(&res).unwrap();
        for fmt in [TraceFormat::Ndjson, TraceFormat::Binary] {
            let bytes = tr.to_bytes(fmt);
            let back = Trace::from_bytes(&bytes).unwrap();
            assert_eq!(tr, back, "{fmt:?}");
        }
    }
}
