//! Supporting utilities: special math functions, CSV output, a scoped
//! thread pool, a micro-benchmark harness (criterion substitute — the
//! offline registry has no `criterion`), and a miniature property-testing
//! harness (`proptest` substitute).

pub mod bench;
pub mod csv;
pub mod logging;
pub mod math;
pub mod quickcheck;
pub mod threadpool;
