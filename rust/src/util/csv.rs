//! Minimal CSV writer for figure data and experiment reports.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// New table with the given column names.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a row of already-formatted cells; must match the header arity.
    pub fn push_raw(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "csv row arity");
        self.rows.push(row);
    }

    /// Append a row of f64 cells (formatted with up to 9 significant
    /// digits, NaN rendered as empty).
    pub fn push(&mut self, row: &[f64]) {
        self.push_raw(
            row.iter()
                .map(|v| {
                    if v.is_nan() {
                        String::new()
                    } else {
                        format!("{v:.9}")
                    }
                })
                .collect(),
        );
    }

    /// Render to CSV text (RFC-4180-style quoting for cells containing
    /// commas, quotes, or newlines).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, cells: &[String]| {
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if cell.contains([',', '"', '\n']) {
                    let escaped = cell.replace('"', "\"\"");
                    let _ = write!(out, "\"{escaped}\"");
                } else {
                    out.push_str(cell);
                }
            }
            out.push('\n');
        };
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_quoting() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.push(&[1.0, 2.5]);
        c.push_raw(vec!["he,llo".into(), "wo\"rld".into()]);
        let s = c.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert!(lines[1].starts_with("1.0"));
        assert_eq!(lines[2], "\"he,llo\",\"wo\"\"rld\"");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn nan_rendered_empty() {
        let mut c = Csv::new(vec!["x"]);
        c.push(&[f64::NAN]);
        assert_eq!(c.to_string().lines().nth(1).unwrap(), "");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.push(&[1.0]);
    }
}
