//! Miniature property-testing harness (offline substitute for `proptest`).
//!
//! Generates seeded-random inputs, runs a property over many cases, and on
//! failure reports the failing case number and seed so the case can be
//! replayed deterministically. Used for the coordinator/simulator
//! invariants listed in DESIGN.md §6.

use crate::rng::{Pcg64, Rng};

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Master seed; each case derives `seed + case_index` streams.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC0FFEE }
    }
}

/// Source of randomness handed to generators.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }
    /// Uniform u64 in `[lo, hi)`.
    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.rng.next_below(hi - lo)
    }
    /// Uniform usize in `[lo, hi)`.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_range(lo as u64, hi as u64) as usize
    }
    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }
    /// A fresh child RNG (for handing into simulations).
    pub fn rng(&mut self) -> Pcg64 {
        Pcg64::seed_from_u64(self.rng.next_u64())
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with the case index
/// and seed on the first failure (returning `Err(reason)` fails the case).
pub fn check<G, T, P>(cfg: Config, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    T: std::fmt::Debug,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg64::seed_from_u64(case_seed) };
        let input = generate(&mut g);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {case_seed}): {reason}\ninput: {input:?}",
                cfg.cases
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check(
            Config { cases: 64, seed: 1 },
            |g| (g.f64_range(0.0, 10.0), g.f64_range(0.0, 10.0)),
            |&(a, b)| {
                if a + b >= a.max(b) - 1e-12 {
                    Ok(())
                } else {
                    Err("sum smaller than max".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        check(
            Config { cases: 64, seed: 2 },
            |g| g.u64_range(0, 100),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
    }

    #[test]
    fn generators_in_range() {
        let mut g = Gen { rng: Pcg64::seed_from_u64(3) };
        for _ in 0..1000 {
            let x = g.f64_range(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let u = g.usize_range(3, 9);
            assert!((3..9).contains(&u));
        }
    }
}
