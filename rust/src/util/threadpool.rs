//! A small fixed-size thread pool used by the sweep executor and the
//! sharded single-run executor.
//!
//! The offline registry has no `rayon`/`tokio`; sweeps are embarrassingly
//! parallel (one simulation per configuration × replication), so a simple
//! channel-fed pool is all the coordinator needs.
//!
//! Panic policy: a panicking job must not shrink the pool. Each job runs
//! under `catch_unwind`, so the worker survives and keeps draining the
//! queue; [`ThreadPool::map`] additionally captures the panic payload and
//! surfaces it to the caller as an `Err` instead of a dead slot.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Render a `catch_unwind` payload as the panic message (the common
/// `&str` / `String` payloads; anything else gets a generic label).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (≥ 1).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("tt-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            // The guard is dropped before the job runs, so
                            // the lock can no longer be poisoned by a job
                            // panic — but recover anyway rather than
                            // cascade one poisoned worker into a dead pool.
                            let guard =
                                rx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                            guard.recv()
                        };
                        match job {
                            // A panicking job must not take this worker
                            // down with it: swallow the unwind and keep
                            // serving the queue. `map` observes panics
                            // through its own per-job catch; bare
                            // `execute` jobs have no return channel.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { sender: Some(sender), workers }
    }

    /// Pool sized to the machine: `available_parallelism`, capped.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.clamp(1, 32))
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    ///
    /// A panicking job yields `Err` carrying the first panic's payload
    /// (remaining jobs still run to completion; the pool stays usable).
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Result<Vec<U>, String>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, thread::Result<U>)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<(usize, String)> = None;
        for (i, out) in rx {
            match out {
                Ok(v) => slots[i] = Some(v),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some((i, panic_message(payload.as_ref())));
                    }
                }
            }
        }
        if let Some((i, msg)) = first_panic {
            return Err(format!("pool job {i} panicked: {msg}"));
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| format!("pool job {i} produced no result")))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<i32>>(), |x| x * x).unwrap();
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x).unwrap();
        assert!(out.is_empty());
    }

    /// A panicking map job surfaces as an error — with its payload — and
    /// the pool keeps working afterwards (the regression this module's
    /// panic policy exists for: no silently dead workers, no poisoned
    /// receiver, no bare `expect` blowup in `map`).
    #[test]
    fn panicking_map_job_is_an_error_not_a_dead_worker() {
        let pool = ThreadPool::new(2);
        let err = pool
            .map(vec![1i32, 2, 3], |x| {
                if x == 2 {
                    panic!("boom on {x}");
                }
                x * 10
            })
            .unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("boom on 2"), "payload lost: {err}");
        // Every worker is still alive: a full follow-up map succeeds even
        // on a pool with as many panics behind it as workers.
        let err2 = pool.map(vec![0i32, 0], |_| -> i32 { panic!("again") }).unwrap_err();
        assert!(err2.contains("again"), "{err2}");
        let out = pool.map((0..16).collect::<Vec<i32>>(), |x| x + 1).unwrap();
        assert_eq!(out, (1..17).collect::<Vec<i32>>());
    }

    /// A panicking fire-and-forget job doesn't kill later jobs either.
    #[test]
    fn panicking_execute_job_keeps_worker_alive() {
        let pool = ThreadPool::new(1); // single worker: a dead one would hang us
        pool.execute(|| panic!("detached boom"));
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
