//! A small fixed-size thread pool used by the sweep executor.
//!
//! The offline registry has no `rayon`/`tokio`; sweeps are embarrassingly
//! parallel (one simulation per configuration × replication), so a simple
//! channel-fed pool is all the coordinator needs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (≥ 1).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("tt-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { sender: Some(sender), workers }
    }

    /// Pool sized to the machine: `available_parallelism`, capped.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.clamp(1, 32))
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, U)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = f(item);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.expect("worker completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }
}
