//! Micro-benchmark harness — criterion substitute for the offline
//! toolchain. Provides warmup, calibrated iteration counts, and robust
//! summary statistics; used by every target under `rust/benches/`
//! (`[[bench]] harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark: wall-time statistics over measured iterations.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Number of measured iterations.
    pub iters: u64,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Median time per iteration (per-batch estimate).
    pub median: Duration,
    /// 99th-percentile per-iteration time (per-batch estimate).
    pub p99: Duration,
    /// Minimum observed per-iteration time.
    pub min: Duration,
}

impl BenchResult {
    /// Iterations per second based on the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

/// Benchmark runner with configurable time budgets.
pub struct Bencher {
    /// Warmup budget before measurement.
    pub warmup: Duration,
    /// Measurement budget.
    pub measure: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(200), Duration::from_millis(800))
    }
}

impl Bencher {
    /// Runner with explicit warmup/measure budgets.
    pub fn new(warmup: Duration, measure: Duration) -> Self {
        // Allow quick CI runs: TT_BENCH_FAST=1 shrinks the budgets 10x.
        let fast = std::env::var("TT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        let (warmup, measure) = if fast {
            (warmup / 10, measure / 10)
        } else {
            (warmup, measure)
        };
        Self { warmup, measure, results: Vec::new() }
    }

    /// Benchmark `f`, which performs **one** unit of work per call and
    /// returns a value that is black-boxed to defeat dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and per-call cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_call = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        // Batch so each sample is >= ~100 µs to dodge timer noise.
        let batch = ((100e-6 / per_call.max(1e-12)).ceil() as u64).clamp(1, 1 << 22);

        let mut samples: Vec<f64> = Vec::new(); // per-iteration secs per batch
        let mut total_iters: u64 = 0;
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.measure || samples.len() < 5 {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pick = |q: f64| -> f64 {
            let idx = ((samples.len() as f64 - 1.0) * q).round() as usize;
            samples[idx]
        };
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(pick(0.5)),
            p99: Duration::from_secs_f64(pick(0.99)),
            min: Duration::from_secs_f64(samples[0]),
        };
        println!(
            "bench {:<44} mean {:>12?} median {:>12?} p99 {:>12?} ({} iters)",
            result.name, result.mean, result.median, result.p99, result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn finish(&self) {
        println!("\n== bench summary ==");
        for r in &self.results {
            println!(
                "{:<44} {:>12?}/iter  {:>14.1} iter/s",
                r.name,
                r.mean,
                r.throughput()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("TT_BENCH_FAST", "1");
        let mut b = Bencher::new(Duration::from_millis(20), Duration::from_millis(50));
        let r = b.bench("noop-ish", || 1 + 1);
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() < 1_000_000);
        assert!(r.min <= r.median && r.median <= r.p99);
    }
}
