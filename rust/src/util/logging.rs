//! Minimal `log` backend (env_logger substitute): stderr, level filter
//! from `TT_LOG` (`error|warn|info|debug|trace`, default `warn`).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent); level from `TT_LOG`.
pub fn init() {
    let level = match std::env::var("TT_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test (expected in test output)");
    }
}
