//! Minimal `log` backend (env_logger substitute): stderr, level filter
//! from `TT_LOG` (`off|error|warn|info|debug|trace`, default `warn`).
//! Unrecognized `TT_LOG` values fall back to `warn` with a one-time
//! stderr warning instead of silently defaulting. The obs progress
//! heartbeat ([`crate::obs::progress`]) emits through [`stderr_line`],
//! the same formatting backend the logger uses.

use log::{Level, LevelFilter, Metadata, Record};

/// The shared stderr line format: `[TAG ] target: message`. Both the
/// `log` backend and the obs heartbeat route through here so every
/// diagnostic line on stderr has one shape.
pub fn stderr_line(tag: &str, target: &str, msg: &str) {
    eprintln!("[{tag}] {target}: {msg}");
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        stderr_line(tag, record.target(), &record.args().to_string());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Resolve a `TT_LOG` value to a level filter. Returns the filter and
/// whether the value was recognized (`warn` is now an accepted spelling,
/// not just the silent default).
fn level_from(value: Option<&str>) -> (LevelFilter, bool) {
    match value {
        Some("error") => (LevelFilter::Error, true),
        Some("warn") => (LevelFilter::Warn, true),
        Some("info") => (LevelFilter::Info, true),
        Some("debug") => (LevelFilter::Debug, true),
        Some("trace") => (LevelFilter::Trace, true),
        Some("off") => (LevelFilter::Off, true),
        None => (LevelFilter::Warn, true),
        Some(_) => (LevelFilter::Warn, false),
    }
}

/// Install the logger (idempotent); level from `TT_LOG`.
pub fn init() {
    let var = std::env::var("TT_LOG").ok();
    let (level, recognized) = level_from(var.as_deref());
    if !recognized {
        // One-time: init is guarded by set_logger's first-wins semantics
        // below, but warn even on repeat inits only once per process.
        static WARNED: std::sync::atomic::AtomicBool =
            std::sync::atomic::AtomicBool::new(false);
        if !WARNED.swap(true, std::sync::atomic::Ordering::Relaxed) {
            stderr_line(
                "WARN ",
                "tiny_tasks::util::logging",
                &format!(
                    "unrecognized TT_LOG value {:?}; expected \
                     off|error|warn|info|debug|trace, defaulting to warn",
                    var.as_deref().unwrap_or("")
                ),
            );
        }
    }
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test (expected in test output)");
    }

    #[test]
    fn warn_is_an_accepted_spelling() {
        assert_eq!(level_from(Some("warn")), (LevelFilter::Warn, true));
    }

    #[test]
    fn unrecognized_values_flag_and_default() {
        assert_eq!(level_from(Some("verbose")), (LevelFilter::Warn, false));
        assert_eq!(level_from(None), (LevelFilter::Warn, true));
        for v in ["off", "error", "info", "debug", "trace"] {
            assert!(level_from(Some(v)).1, "{v} should be recognized");
        }
    }
}
