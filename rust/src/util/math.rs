//! Special functions used by the analysis layer.

/// Natural log of the Gamma function (Lanczos approximation, g = 7,
/// n = 9 coefficients; |rel err| < 1e-13 on the positive axis).
pub fn ln_gamma(x: f64) -> f64 {
    // Lanczos coefficients (g = 7).
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1−x) = π / sin(πx).
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// `ln(n!)` via `ln_gamma`.
pub fn ln_factorial(n: u32) -> f64 {
    ln_gamma(n as f64 + 1.0)
}

/// n-th harmonic number `H_n = sum_{i=1}^{n} 1/i`.
///
/// Exact summation for n ≤ 10^6, asymptotic expansion beyond (the paper's
/// stability discussion uses `H_l ≈ γ + ln l`).
pub fn harmonic(n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if n <= 1_000_000 {
        (1..=n).map(|i| 1.0 / i as f64).sum()
    } else {
        const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;
        let nf = n as f64;
        nf.ln() + EULER_GAMMA + 1.0 / (2.0 * nf) - 1.0 / (12.0 * nf * nf)
    }
}

/// Numerically stable `ln(1 + x)`— thin wrapper kept for clarity at call
/// sites in the envelope computations.
#[inline]
pub fn ln1p(x: f64) -> f64 {
    x.ln_1p()
}

/// Golden-section minimization of a unimodal function on `[a, b]`.
///
/// Used to optimize the free MGF parameter θ in the network-calculus
/// bounds; falls back gracefully for non-unimodal inputs by returning the
/// best point probed.
pub fn golden_section_min<F: FnMut(f64) -> f64>(
    mut f: F,
    mut a: f64,
    mut b: f64,
    tol: f64,
    max_iter: usize,
) -> (f64, f64) {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut c = b - INV_PHI * (b - a);
    let mut d = a + INV_PHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    let mut best = if fc < fd { (c, fc) } else { (d, fd) };
    for _ in 0..max_iter {
        if (b - a).abs() < tol {
            break;
        }
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INV_PHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INV_PHI * (b - a);
            fd = f(d);
        }
        if fc < best.1 {
            best = (c, fc);
        }
        if fd < best.1 {
            best = (d, fd);
        }
    }
    best
}

/// Simpson-rule integration of `f` over `[a, b]` with `n` (even) panels.
pub fn simpson<F: FnMut(f64) -> f64>(mut f: F, a: f64, b: f64, n: usize) -> f64 {
    assert!(n >= 2 && n % 2 == 0, "n must be even and >= 2");
    let h = (b - a) / n as f64;
    let mut sum = f(a) + f(b);
    for i in 1..n {
        let x = a + i as f64 * h;
        sum += f(x) * if i % 2 == 1 { 4.0 } else { 2.0 };
    }
    sum * h / 3.0
}

/// Bisection root-finding for a monotone predicate: returns the largest `x`
/// in `[lo, hi]` for which `pred(x)` holds, to absolute tolerance `tol`.
/// Returns `None` if `pred(lo)` is already false.
pub fn bisect_sup<F: FnMut(f64) -> bool>(
    mut pred: F,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> Option<f64> {
    if !pred(lo) {
        return None;
    }
    if pred(hi) {
        return Some(hi);
    }
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        if pred(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..15u32 {
            let exact: f64 = (1..=n as u64).map(|i| (i as f64).ln()).sum();
            assert!(
                (ln_factorial(n) - exact).abs() < 1e-10,
                "n={n}: {} vs {exact}",
                ln_factorial(n)
            );
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Γ(1/2) = sqrt(π).
        let expect = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - expect).abs() < 1e-12);
    }

    #[test]
    fn harmonic_values() {
        assert_eq!(harmonic(0), 0.0);
        assert!((harmonic(1) - 1.0).abs() < 1e-15);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-14);
        // Asymptotic branch continuous with exact branch.
        let exact = harmonic(1_000_000);
        let approx = {
            let nf = 1_000_000f64;
            nf.ln() + 0.577_215_664_901_532_9 + 1.0 / (2.0 * nf) - 1.0 / (12.0 * nf * nf)
        };
        assert!((exact - approx).abs() < 1e-9);
    }

    #[test]
    fn golden_section_finds_parabola_min() {
        let (x, fx) = golden_section_min(|x| (x - 1.7) * (x - 1.7) + 3.0, 0.0, 5.0, 1e-10, 200);
        assert!((x - 1.7).abs() < 1e-6);
        assert!((fx - 3.0).abs() < 1e-10);
    }

    #[test]
    fn simpson_integrates_polynomials_exactly() {
        // Simpson is exact for cubics.
        let i = simpson(|x| x * x * x - 2.0 * x + 1.0, 0.0, 2.0, 2);
        let exact = 4.0 - 4.0 + 2.0;
        assert!((i - exact).abs() < 1e-12);
    }

    #[test]
    fn simpson_exp() {
        let i = simpson(|x| (-x as f64).exp(), 0.0, 10.0, 1000);
        assert!((i - (1.0 - (-10.0f64).exp())).abs() < 1e-9);
    }

    #[test]
    fn bisect_sup_monotone() {
        let s = bisect_sup(|x| x * x <= 2.0, 0.0, 2.0, 1e-9).unwrap();
        assert!((s - 2f64.sqrt()).abs() < 1e-7);
        assert!(bisect_sup(|x| x < -1.0, 0.0, 1.0, 1e-9).is_none());
        assert_eq!(bisect_sup(|_| true, 0.0, 3.0, 1e-9), Some(3.0));
    }
}
