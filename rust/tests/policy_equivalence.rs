//! Dispatch-policy equivalence regressions: the scheduling-policy axis
//! must be invisible until it is used.
//!
//! Two degeneracy ladders are pinned bit-for-bit (`assert_eq!` on f64,
//! no tolerance):
//!
//! 1. `policy = "fcfs"` (an explicit but inactive `[policy]` section)
//!    builds no policy state at all, so every model — with or without
//!    scenario and fault machinery — reproduces the absent-section run
//!    exactly.
//! 2. Single-interval SITA (no boundaries) *does* build policy state,
//!    but its one size group owns the whole cluster, so its dispatch
//!    decisions collapse onto FCFS earliest-free-server and the sojourn
//!    law must match FCFS bitwise.
//!
//! Plus the usual axis guards: per-seed reproducibility for every
//! active policy, a non-degenerate policy genuinely changing the law,
//! priority runs populating per-class summaries, and partitionless
//! models (ideal, fjps) rejecting active policies outright.

use tiny_tasks::config::{
    ArrivalConfig, FaultsConfig, ModelKind, PolicyConfig, PolicyKind, RedundancyConfig,
    ServiceConfig, SimulationConfig, WorkersConfig,
};
use tiny_tasks::sim::{self, RunOptions};

fn base(model: ModelKind, l: usize, k: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.4".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs: 4_000,
        warmup: 400,
        seed: 2027,
        overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

fn policy(kind: PolicyKind) -> PolicyConfig {
    PolicyConfig { kind, ..Default::default() }
}

fn quantiles(cfg: &SimulationConfig) -> (Vec<f64>, f64, f64) {
    let mut res = sim::run(cfg, RunOptions::default()).unwrap();
    let qs = [0.1, 0.5, 0.9, 0.99]
        .iter()
        .map(|&q| res.sojourn_quantile(q))
        .collect();
    (qs, res.sojourn_summary.mean(), res.waiting_quantile(0.9))
}

/// An explicit `policy = "fcfs"` section is bit-for-bit the absent
/// section, for every model.
#[test]
fn fcfs_policy_is_bitwise_default() {
    for (model, l, k) in [
        (ModelKind::SplitMerge, 5, 25),
        (ModelKind::ForkJoinSingleQueue, 5, 25),
        (ModelKind::ForkJoinPerServer, 5, 5),
        (ModelKind::Ideal, 5, 25),
    ] {
        let plain = base(model, l, k);
        let fcfs = SimulationConfig {
            policy: Some(policy(PolicyKind::Fcfs)),
            ..base(model, l, k)
        };
        let (qa, ma, wa) = quantiles(&plain);
        let (qb, mb, wb) = quantiles(&fcfs);
        assert_eq!(qa, qb, "{model}: sojourn quantiles diverge under fcfs policy");
        assert_eq!(ma, mb, "{model}: sojourn mean diverges");
        assert_eq!(wa, wb, "{model}: waiting quantile diverges");
    }
}

/// The fcfs degeneracy composes with the scenario (skewed + redundant)
/// and fault-injection machinery: the policy layer must not disturb
/// either RNG stream.
#[test]
fn fcfs_policy_is_bitwise_with_scenario_and_faults() {
    let scenario = SimulationConfig {
        workers: Some(WorkersConfig::Speeds(vec![1.5, 1.5, 1.0, 0.5, 0.5])),
        redundancy: Some(RedundancyConfig::new(2)),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    let faulty = SimulationConfig {
        faults: Some(FaultsConfig {
            mtbf: 40.0,
            mttr: 1.0,
            task_fail_p: 0.05,
            ..FaultsConfig::default()
        }),
        ..base(ModelKind::SplitMerge, 5, 25)
    };
    for plain in [scenario, faulty] {
        let fcfs = SimulationConfig {
            policy: Some(policy(PolicyKind::Fcfs)),
            ..plain.clone()
        };
        let (qa, ma, wa) = quantiles(&plain);
        let (qb, mb, wb) = quantiles(&fcfs);
        assert_eq!(qa, qb, "{}: quantiles diverge under fcfs policy", plain.model);
        assert_eq!(ma, mb);
        assert_eq!(wa, wb);
    }
}

/// Single-interval SITA (no boundaries): the policy state is live, its
/// one partition is the whole cluster, and the dispatch decisions must
/// collapse onto FCFS bitwise — for both recursion models.
#[test]
fn sita_single_interval_matches_fcfs_bitwise() {
    for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
        let plain = base(model, 5, 25);
        let sita1 = SimulationConfig {
            policy: Some(policy(PolicyKind::Sita)),
            ..base(model, 5, 25)
        };
        let (qa, ma, wa) = quantiles(&plain);
        let (qb, mb, wb) = quantiles(&sita1);
        assert_eq!(qa, qb, "{model}: single-interval SITA must be FCFS");
        assert_eq!(ma, mb, "{model}: sojourn mean diverges");
        assert_eq!(wb, wa, "{model}: waiting quantile diverges");
    }
}

/// The active policies the panel sweeps, with knobs sized for the
/// l = 5, k = 25 shape (mean task size l/k = 0.2 s).
fn active_policies() -> Vec<PolicyConfig> {
    vec![
        PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![0.2],
            ..Default::default()
        },
        PolicyConfig {
            kind: PolicyKind::Priority,
            classes: 2,
            weights: vec![2.0, 1.0],
            ..Default::default()
        },
        PolicyConfig {
            kind: PolicyKind::WorkSteal,
            steal_threshold: 0.2,
            ..Default::default()
        },
    ]
}

/// Fixed seed ⇒ fixed dispatch schedule for every active policy, and a
/// reseed genuinely re-rolls the law.
#[test]
fn policy_runs_reproducible_per_seed() {
    for pol in active_policies() {
        let kind = pol.kind;
        let cfg = SimulationConfig {
            policy: Some(pol),
            ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
        };
        let (qa, ma, wa) = quantiles(&cfg);
        let (qb, mb, wb) = quantiles(&cfg);
        assert_eq!(qa, qb, "{kind}: same seed must give identical quantiles");
        assert_eq!(ma, mb);
        assert_eq!(wa, wb);
        let reseeded = SimulationConfig { seed: cfg.seed ^ 0xBEEF, ..cfg.clone() };
        let (_, mc, _) = quantiles(&reseeded);
        assert_ne!(ma, mc, "{kind}: a reseed must change the sampled law");
    }
}

/// A non-degenerate policy genuinely changes the sojourn law (guards
/// against the policy plumbing silently not reaching the models).
#[test]
fn active_policy_changes_the_distribution() {
    let plain = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let sita = SimulationConfig {
        policy: Some(PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![0.2],
            ..Default::default()
        }),
        ..plain.clone()
    };
    let (qa, _, _) = quantiles(&plain);
    let (qb, _, _) = quantiles(&sita);
    assert_ne!(qa, qb, "a real SITA split must alter the sojourn quantiles");
}

/// Priority runs populate the per-class sojourn summaries: one bucket
/// per class, counts summing to the measured jobs, and the buckets
/// merge identically under sharding.
#[test]
fn priority_run_populates_class_summaries() {
    let cfg = SimulationConfig {
        policy: Some(PolicyConfig {
            kind: PolicyKind::Priority,
            classes: 2,
            weights: vec![2.0, 1.0],
            ..Default::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    let res = sim::run(&cfg, RunOptions::default()).unwrap();
    assert_eq!(res.class_sojourn.len(), 2);
    let total: u64 = res.class_sojourn.iter().map(|s| s.count()).sum();
    assert_eq!(total, res.sojourn_summary.count());
    for (c, s) in res.class_sojourn.iter().enumerate() {
        assert!(s.count() > 0, "class {c} never observed");
    }
    // SITA classes are per-task, so job sojourns stay classless.
    let sita = SimulationConfig {
        policy: Some(PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![0.2],
            ..Default::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    let res = sim::run(&sita, RunOptions::default()).unwrap();
    assert!(res.class_sojourn.is_empty());
}

/// The partitionless models reject active policies with a pointed
/// config error instead of silently running FCFS.
#[test]
fn partitionless_models_reject_active_policies() {
    for model in [ModelKind::Ideal, ModelKind::ForkJoinPerServer] {
        let (l, k) = if model == ModelKind::ForkJoinPerServer { (5, 5) } else { (5, 25) };
        let cfg = SimulationConfig {
            policy: Some(policy(PolicyKind::Sita)),
            ..base(model, l, k)
        };
        let err = sim::run(&cfg, RunOptions::default()).unwrap_err();
        assert!(
            err.contains("policy") || err.contains("dispatch"),
            "{model}: unexpected error text {err:?}"
        );
    }
}
