//! Property tests (mini-quickcheck) for the simulator's two seed-bearing
//! substrates: the server min-heap's ordering contract and the RNG
//! seed-spawning used by the parallel sweep executor.

use tiny_tasks::rng::{spawn_seeds, Pcg64, Rng};
use tiny_tasks::sim::ServerHeap;
use tiny_tasks::util::quickcheck::{check, Config};

/// Heap pop order is nondecreasing in time, regardless of the assign /
/// pop / push interleaving that produced the heap.
#[test]
fn prop_heap_pop_order_nondecreasing() {
    check(
        Config { cases: 96, seed: 0x48EA9 },
        |g| {
            let l = g.usize_range(1, 33);
            let ops = g.usize_range(0, 200);
            let seed = g.u64_range(0, u64::MAX - 1);
            (l, ops, seed)
        },
        |&(l, ops, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let mut heap = ServerHeap::new(l, 0.0);
            // Random mix of root-assigns and pop/push pairs.
            for _ in 0..ops {
                if rng.next_below(2) == 0 {
                    let (t, _) = heap.peek();
                    heap.assign(t + rng.next_f64() * 3.0);
                } else {
                    let r = 1 + rng.next_below((l as u64).min(4)) as usize;
                    let mut picks = Vec::new();
                    for _ in 0..r {
                        picks.push(heap.pop());
                    }
                    for (t, id) in picks {
                        heap.push(t + rng.next_f64(), id);
                    }
                }
            }
            // Drain by popping: times must come out nondecreasing and
            // every server id exactly once.
            let mut prev = f64::NEG_INFINITY;
            let mut ids = std::collections::BTreeSet::new();
            for _ in 0..l {
                let (t, id) = heap.pop();
                if t < prev {
                    return Err(format!("pop order decreased: {t} after {prev}"));
                }
                prev = t;
                ids.insert(id);
            }
            if ids.len() != l {
                return Err(format!("{} distinct ids for {l} servers", ids.len()));
            }
            Ok(())
        },
    );
}

/// Peek/assign agrees with a naive min-scan under random durations
/// (the heap is the simulator's innermost loop — this is the oracle).
#[test]
fn prop_heap_matches_naive_min_scan() {
    check(
        Config { cases: 48, seed: 0x9EA9 },
        |g| {
            let l = g.usize_range(1, 20);
            let steps = g.usize_range(1, 300);
            let seed = g.u64_range(0, u64::MAX - 1);
            (l, steps, seed)
        },
        |&(l, steps, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let mut heap = ServerHeap::new(l, 0.0);
            let mut naive = vec![0.0f64; l];
            for _ in 0..steps {
                let dur = rng.next_f64() * 2.0;
                let (t_heap, _) = heap.peek();
                let &t_naive = naive
                    .iter()
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                    .unwrap();
                if t_heap != t_naive {
                    return Err(format!("root {t_heap} != naive min {t_naive}"));
                }
                let idx = naive
                    .iter()
                    .position(|&t| t == t_naive)
                    .unwrap();
                heap.assign(t_heap + dur);
                naive[idx] = t_naive + dur;
            }
            Ok(())
        },
    );
}

/// `spawn_seeds`: distinct seeds for any (master, count), prefix
/// stability (the first n seeds do not depend on the requested count),
/// and distinct masters give distinct seed sets.
#[test]
fn prop_spawn_seeds_distinct_and_prefix_stable() {
    check(
        Config { cases: 64, seed: 0x5EED5 },
        |g| {
            let master = g.u64_range(0, u64::MAX - 1);
            let count = g.usize_range(1, 257);
            (master, count)
        },
        |&(master, count)| {
            let seeds = spawn_seeds(master, count);
            if seeds.len() != count {
                return Err("wrong count".into());
            }
            let set: std::collections::BTreeSet<u64> = seeds.iter().copied().collect();
            if set.len() != count {
                return Err(format!("collision among {count} seeds"));
            }
            // Prefix stability: adding points to a sweep must not reseed
            // the existing points.
            let longer = spawn_seeds(master, count + 8);
            if longer[..count] != seeds[..] {
                return Err("prefix not stable under larger count".into());
            }
            let other = spawn_seeds(master.wrapping_add(1), count);
            if other == seeds {
                return Err("adjacent masters produced identical seeds".into());
            }
            Ok(())
        },
    );
}

/// Stream independence: the PCG64 streams spawned from adjacent child
/// seeds are decorrelated — their outputs differ immediately and their
/// uniform means stay near 1/2 even when XORed pairwise (a cheap
/// cross-correlation proxy).
#[test]
fn prop_spawned_streams_independent() {
    check(
        Config { cases: 24, seed: 0x17EA8 },
        |g| g.u64_range(0, u64::MAX - 1),
        |&master| {
            let seeds = spawn_seeds(master, 2);
            let mut a = Pcg64::seed_from_u64(seeds[0]);
            let mut b = Pcg64::seed_from_u64(seeds[1]);
            let n = 4_096;
            let mut equal = 0usize;
            let mut xor_bits = 0u32;
            let mut sum_a = 0.0;
            let mut sum_b = 0.0;
            for _ in 0..n {
                let x = a.next_u64();
                let y = b.next_u64();
                if x == y {
                    equal += 1;
                }
                xor_bits += (x ^ y).count_ones();
                sum_a += (x >> 11) as f64 / (1u64 << 53) as f64;
                sum_b += (y >> 11) as f64 / (1u64 << 53) as f64;
            }
            if equal > 0 {
                return Err(format!("{equal} identical outputs in lockstep"));
            }
            // XOR of independent uniform bit streams is uniform: expect
            // ~32 set bits per word, far from 0 (identical) or 64.
            let mean_bits = xor_bits as f64 / n as f64;
            if !(28.0..36.0).contains(&mean_bits) {
                return Err(format!("xor bit density {mean_bits} suggests correlation"));
            }
            for (tag, s) in [("a", sum_a), ("b", sum_b)] {
                let mean = s / n as f64;
                if (mean - 0.5).abs() > 0.03 {
                    return Err(format!("stream {tag} mean {mean} off 1/2"));
                }
            }
            Ok(())
        },
    );
}
