//! Fault-injection regressions: degenerate equivalence, determinism,
//! exact retry accounting, sharded fault-stat merging, and the
//! granularity/fault-tolerance interaction the `figure faults` panel
//! plots.
//!
//! The degeneracy tests are bit-for-bit (`assert_eq!` on f64), not
//! tolerance: an inactive `[faults]` section resolves to no injector at
//! all, so the engines must take exactly their seed-era code paths.

use tiny_tasks::config::{
    ArrivalConfig, FaultsConfig, ModelKind, OverheadConfig, ServiceConfig, SimulationConfig,
};
use tiny_tasks::dist::Exponential;
use tiny_tasks::sim::{
    self, Calendar, Discipline, FaultInjector, OverheadModel, RunOptions, TraceLog, Workload,
};
use tiny_tasks::trace::cause;

fn base(model: ModelKind, l: usize, k: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.4".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs: 4_000,
        warmup: 400,
        seed: 2026,
        overhead: Some(OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

fn quantiles(cfg: &SimulationConfig) -> (Vec<f64>, f64, f64) {
    let mut res = sim::run(cfg, RunOptions::default()).unwrap();
    let qs = [0.1, 0.5, 0.9, 0.99]
        .iter()
        .map(|&q| res.sojourn_quantile(q))
        .collect();
    (qs, res.sojourn_summary.mean(), res.waiting_quantile(0.9))
}

/// An inactive `[faults]` section (every mechanism off — the parsed
/// default) is bit-for-bit the seed engines, for every model.
#[test]
fn inactive_faults_bitwise_equal_to_seed_engines() {
    for (model, l, k) in [
        (ModelKind::SplitMerge, 5, 25),
        (ModelKind::ForkJoinSingleQueue, 5, 25),
        (ModelKind::ForkJoinPerServer, 5, 5),
        (ModelKind::Ideal, 5, 25),
    ] {
        let plain = base(model, l, k);
        let degenerate = SimulationConfig {
            faults: Some(FaultsConfig::default()),
            ..base(model, l, k)
        };
        let (qa, ma, wa) = quantiles(&plain);
        let (qb, mb, wb) = quantiles(&degenerate);
        assert_eq!(qa, qb, "{model}: sojourn quantiles diverge");
        assert_eq!(ma, mb, "{model}: sojourn mean diverges");
        assert_eq!(wa, wb, "{model}: waiting quantile diverges");
    }
}

/// Fixed seed ⇒ fixed crash/retry schedule: two runs of an actively
/// faulty config agree bitwise, and the fault stats genuinely populate.
#[test]
fn fault_schedules_reproducible_per_seed() {
    let cfg = SimulationConfig {
        faults: Some(FaultsConfig {
            mtbf: 40.0,
            mttr: 1.0,
            task_fail_p: 0.05,
            backoff_base: 0.01,
            ..FaultsConfig::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    let a = sim::run(&cfg, RunOptions::default()).unwrap();
    let b = sim::run(&cfg, RunOptions::default()).unwrap();
    assert_eq!(a.sojourn_summary.mean(), b.sojourn_summary.mean());
    assert_eq!(a.lost_summary.mean(), b.lost_summary.mean());
    assert_eq!(a.retry_summary.mean(), b.retry_summary.mean());
    assert!(a.retry_summary.mean() > 0.0, "failures configured but no retries");
    assert!(a.lost_summary.mean() > 0.0, "retries without lost server time");
    // A different fault seed re-rolls the schedules without touching the
    // workload stream — the law changes, so the samples must too.
    let mut faults = cfg.faults.unwrap();
    faults.seed = 99;
    let reseeded = sim::run(
        &SimulationConfig { faults: Some(faults), ..cfg.clone() },
        RunOptions::default(),
    )
    .unwrap();
    assert_ne!(
        reseeded.lost_summary.mean(),
        a.lost_summary.mean(),
        "fault seed must drive the fault schedule"
    );
}

/// Faults only ever delay work (no speculation): with the identical
/// workload stream, the faulty run's mean sojourn strictly dominates
/// the fault-free run's.
#[test]
fn faults_degrade_sojourn_monotonically() {
    let plain = base(ModelKind::ForkJoinSingleQueue, 4, 16);
    let faulty = SimulationConfig {
        faults: Some(FaultsConfig {
            mtbf: 25.0,
            mttr: 2.0,
            task_fail_p: 0.1,
            backoff_base: 0.05,
            ..FaultsConfig::default()
        }),
        ..plain.clone()
    };
    let (_, mean_plain, _) = quantiles(&plain);
    let (_, mean_faulty, _) = quantiles(&faulty);
    assert!(
        mean_faulty > mean_plain,
        "crashes + failed attempts must slow jobs down: {mean_faulty} vs {mean_plain}"
    );
}

/// Exact retry accounting, checked against the v3 trace: with a
/// deterministic per-attempt overhead `c`, every job's charged task
/// overhead is (k + retries) × c, its lost work is exactly the summed
/// service of its failed attempts, and attempt counters line up.
#[test]
fn retry_accounting_matches_trace_exactly() {
    let c = 0.02;
    let k = 8usize;
    let cfg = SimulationConfig {
        jobs: 400,
        warmup: 0,
        overhead: Some(OverheadConfig {
            c_task_ts: c,
            mu_task_ts: f64::INFINITY, // deterministic attempt overhead
            c_job_pd: 0.0,
            c_task_pd: 0.0,
        }),
        faults: Some(FaultsConfig {
            task_fail_p: 0.3,
            max_retries: 3,
            backoff_base: 0.05,
            ..FaultsConfig::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 4, k)
    };
    let res = sim::run(
        &cfg,
        RunOptions { record_jobs: true, trace: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(res.jobs.len(), 400);
    let events = res.trace.events();
    let winners = events.iter().filter(|e| e.winner).count();
    assert_eq!(winners, 400 * k, "exactly one winning attempt per task");
    assert!(
        events.iter().any(|e| e.cause == cause::FAILED),
        "p = 0.3 over 3200 tasks must produce failures"
    );
    for job in &res.jobs {
        let id = job.index as u32;
        let failed: Vec<_> = events
            .iter()
            .filter(|e| e.job == id && e.cause == cause::FAILED)
            .collect();
        assert_eq!(
            failed.len() as u32,
            job.retries,
            "job {id}: failed-attempt rows vs retry counter"
        );
        let attempts = k as u32 + job.retries;
        assert!(
            (job.task_overhead - f64::from(attempts) * c).abs() < 1e-9,
            "job {id}: overhead {} != {attempts} attempts x {c}",
            job.task_overhead
        );
        let lost: f64 = failed.iter().map(|e| e.end - e.start).sum();
        assert!(
            (job.lost_work - lost).abs() < 1e-9,
            "job {id}: lost_work {} vs trace {lost}",
            job.lost_work
        );
        // The winning attempt of a task with f failures is attempt f+1.
        for t in 0..k as u32 {
            let fails = failed.iter().filter(|e| e.task == t).count() as u32;
            let win = events
                .iter()
                .find(|e| e.job == id && e.task == t && e.winner)
                .expect("winner row");
            assert_eq!(win.attempt, fails + 1, "job {id} task {t}");
            assert_eq!(win.cause, cause::NONE);
        }
    }
}

/// Speculative re-execution hedges stragglers: backups launch, their
/// cancelled copies are billed as redundant work, and every job departs.
#[test]
fn speculation_populates_redundant_work() {
    let cfg = SimulationConfig {
        jobs: 3_000,
        warmup: 300,
        overhead: None,
        faults: Some(FaultsConfig { spec_timeout: 2.0, ..FaultsConfig::default() }),
        ..base(ModelKind::ForkJoinSingleQueue, 4, 8)
    };
    let res = sim::run(&cfg, RunOptions::default()).unwrap();
    assert_eq!(res.sojourn.len(), 3_000);
    assert!(
        res.redundant_summary.mean() > 0.0,
        "exp service exceeds 2 x E[task] often; backups must fire"
    );
    // Speculation is a hedge, not a failure: no retries, nothing lost.
    assert_eq!(res.retry_summary.mean(), 0.0);
    assert_eq!(res.lost_summary.mean(), 0.0);
}

/// Sharded runs merge fault statistics: the thread count is
/// unobservable (bitwise), a single shard is the unsharded engine, and
/// (seed, shard count) pins the merged result.
#[test]
fn sharded_runs_merge_fault_stats() {
    let cfg = SimulationConfig {
        jobs: 6_000,
        faults: Some(FaultsConfig {
            mtbf: 40.0,
            mttr: 1.0,
            task_fail_p: 0.05,
            backoff_base: 0.01,
            ..FaultsConfig::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 4, 16)
    };
    let serial =
        sim::run(&cfg, RunOptions { shards: 4, threads: 1, ..Default::default() }).unwrap();
    let parallel =
        sim::run(&cfg, RunOptions { shards: 4, threads: 4, ..Default::default() }).unwrap();
    for (a, b) in [
        (&serial.lost_summary, &parallel.lost_summary),
        (&serial.retry_summary, &parallel.retry_summary),
        (&serial.redundant_summary, &parallel.redundant_summary),
    ] {
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.variance(), b.variance());
    }
    assert_eq!(serial.lost_summary.count(), cfg.jobs as u64);
    assert!(serial.retry_summary.mean() > 0.0, "fault stats lost in the merge");
    // Replication shards draw independent fault schedules, so shard 0
    // alone must not reproduce the merged stream — but the same (seed,
    // shard count) must.
    let again =
        sim::run(&cfg, RunOptions { shards: 4, threads: 2, ..Default::default() }).unwrap();
    assert_eq!(serial.lost_summary.mean(), again.lost_summary.mean());
    let unsharded = sim::run(&cfg, RunOptions::default()).unwrap();
    assert_eq!(unsharded.lost_summary.count(), cfg.jobs as u64);
    let single =
        sim::run(&cfg, RunOptions { shards: 1, threads: 4, ..Default::default() }).unwrap();
    assert_eq!(unsharded.lost_summary.mean(), single.lost_summary.mean());
    assert_eq!(unsharded.retry_summary.mean(), single.retry_summary.mean());
}

/// The `figure faults` acceptance property: at constant mean job
/// workload, the server time lost per failure event shrinks with k —
/// a failure wastes at most one task, and tasks shrink as ~1/k.
#[test]
fn work_lost_per_failure_shrinks_with_k() {
    let ratio = |k: usize| {
        let cfg = SimulationConfig {
            arrival: ArrivalConfig { interarrival: "exp:0.5".into() },
            service: ServiceConfig { execution: format!("exp:{}", k as f64 / 4.0) },
            jobs: 4_000,
            warmup: 400,
            overhead: None,
            faults: Some(FaultsConfig {
                task_fail_p: 0.1,
                backoff_base: 0.01,
                ..FaultsConfig::default()
            }),
            ..base(ModelKind::ForkJoinSingleQueue, 4, k)
        };
        let res = sim::run(&cfg, RunOptions::default()).unwrap();
        let retries = res.retry_summary.mean();
        assert!(retries > 0.0, "k={k}: no retries observed");
        res.lost_summary.mean() / retries
    };
    let (coarse, fine) = (ratio(8), ratio(64));
    assert!(
        fine < coarse / 2.0,
        "lost work per retry must shrink with k: k=8 {coarse} vs k=64 {fine}"
    );
}

/// The calendar engine under faults: deterministic per seed, every job
/// departs, losses and retries are recorded, and crashes slow the
/// system down relative to its own fault-free run on the same workload
/// stream.
#[test]
fn calendar_engine_runs_faults_deterministically() {
    let (l, k, n) = (4usize, 16usize, 2_000usize);
    let mu = k as f64 / l as f64;
    let faults = FaultsConfig {
        mtbf: 30.0,
        mttr: 1.0,
        task_fail_p: 0.05,
        backoff_base: 0.01,
        ..FaultsConfig::default()
    };
    let oh = OverheadModel::none();
    let run_cal = |inject: bool| {
        let mut w =
            Workload::new(Exponential::new(0.4).into(), Exponential::new(mu).into(), 7);
        let injector = inject.then(|| FaultInjector::new(faults, l, 7, 1.0 / mu));
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32])
            .with_faults(injector);
        let mut tr = TraceLog::disabled();
        cal.run(n, &mut w, &oh, &mut tr)
    };
    let faulty = run_cal(true);
    assert_eq!(faulty.len(), n, "every job must depart despite crashes");
    let lost: f64 = faulty.iter().map(|r| r.lost_work).sum();
    let retries: u32 = faulty.iter().map(|r| r.retries).sum();
    assert!(lost > 0.0 && retries > 0, "fault accounting missing: {lost} / {retries}");
    let again = run_cal(true);
    for (a, b) in faulty.iter().zip(&again) {
        assert_eq!(a.departure, b.departure, "calendar fault run not deterministic");
        assert_eq!(a.lost_work, b.lost_work);
        assert_eq!(a.retries, b.retries);
    }
    let plain = run_cal(false);
    let mean = |rs: &[tiny_tasks::sim::JobRecord]| {
        rs.iter().map(|r| r.sojourn()).sum::<f64>() / rs.len() as f64
    };
    assert!(
        mean(&faulty) > mean(&plain),
        "faults must delay the calendar engine: {} vs {}",
        mean(&faulty),
        mean(&plain)
    );
}
