//! Simulation ⟷ analysis consistency: the network-calculus bounds must
//! dominate simulated quantiles at the same ε, stability theory must
//! match detection, and the direct-refinement (Sec. 4.1) ordering must
//! hold in simulation, not just in the bounds.

use tiny_tasks::analysis::{self, BoundModel, BoundParams};
use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::sim::{self, RunOptions};

fn cfg(model: ModelKind, l: usize, k: usize, lambda: f64, mu: f64, jobs: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
        service: ServiceConfig { execution: format!("exp:{mu}") },
        jobs,
        warmup: jobs / 10,
        seed: 1234,
        overhead: None,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// Bounds dominate simulation across a parameter grid (the Fig. 8/13
/// relationship), for both split-merge and fork-join.
#[test]
fn bounds_dominate_simulated_quantiles_across_grid() {
    let eps = 0.01;
    for &(l, kappa, lambda) in
        &[(10usize, 4usize, 0.5), (10, 16, 0.6), (25, 8, 0.4), (50, 12, 0.5)]
    {
        let k = kappa * l;
        let mu = k as f64 / l as f64;
        for (bm, mk) in [
            (BoundModel::ForkJoinTiny, ModelKind::ForkJoinSingleQueue),
            (BoundModel::SplitMergeTiny, ModelKind::SplitMerge),
        ] {
            let params = BoundParams { l, k, lambda, mu, epsilon: eps, overhead: None };
            let Some(bound) = analysis::sojourn_bound(bm, &params) else {
                continue; // unstable: nothing to dominate
            };
            let mut res = sim::run(&cfg(mk, l, k, lambda, mu, 20_000), RunOptions::default())
                .unwrap();
            let sim_q = res.sojourn_quantile(1.0 - eps);
            assert!(
                sim_q <= bound,
                "{bm:?} l={l} k={k} λ={lambda}: sim {sim_q} > bound {bound}"
            );
        }
    }
}

/// Waiting-time bounds dominate simulated waiting quantiles too.
#[test]
fn waiting_bounds_dominate() {
    let (l, k, lambda) = (10usize, 60usize, 0.5);
    let mu = k as f64 / l as f64;
    let eps = 0.01;
    let params = BoundParams { l, k, lambda, mu, epsilon: eps, overhead: None };
    let bound = analysis::waiting_bound(BoundModel::ForkJoinTiny, &params).unwrap();
    let mut res = sim::run(
        &cfg(ModelKind::ForkJoinSingleQueue, l, k, lambda, mu, 30_000),
        RunOptions::default(),
    )
    .unwrap();
    let sim_w = res.waiting_quantile(1.0 - eps);
    assert!(sim_w <= bound, "waiting: sim {sim_w} > bound {bound}");
}

/// Eq. 20 predicts the simulated stability transition: just inside the
/// region the sojourn process is stationary; well outside it diverges.
#[test]
fn eq20_matches_simulated_transition() {
    let (l, k) = (20usize, 100usize); // κ = 5 → ρ* ≈ 0.664
    let rho_star = analysis::stability::sm_tiny_tasks(l, k);
    let mu = k as f64 / l as f64;
    let run_at = |rho: f64| {
        let lambda = rho * mu * l as f64 / k as f64;
        let c = SimulationConfig {
            warmup: 0,
            ..cfg(ModelKind::SplitMerge, l, k, lambda, mu, 10_000)
        };
        sim::stability::detect(&c, 1.05).unwrap()
    };
    // Clear separation on both sides: the detector is a heuristic (it
    // flags sustained growth over run thirds) and at loads just inside
    // the boundary the queue's slow relaxation looks like growth.
    assert_eq!(run_at(rho_star * 0.5), sim::stability::Stability::Stable);
    assert_eq!(run_at(rho_star * 1.4), sim::stability::Stability::Unstable);
}

/// Direct refinement in *simulation* (Sec. 4.1): κl tiny Exp(μ) tasks
/// beat l big Erlang(κ, μ) tasks for the same workload distribution.
#[test]
fn direct_refinement_simulated() {
    let (l, kappa) = (10usize, 8u32);
    let mu = kappa as f64; // utilization = λ
    let lambda = 0.45;
    let tiny = cfg(
        ModelKind::SplitMerge,
        l,
        kappa as usize * l,
        lambda,
        mu,
        20_000,
    );
    let big = SimulationConfig {
        service: ServiceConfig { execution: format!("erlang:{kappa}:{mu}") },
        ..cfg(ModelKind::SplitMerge, l, l, lambda, mu, 20_000)
    };
    let mut tiny_res = sim::run(&tiny, RunOptions::default()).unwrap();
    let mut big_res = sim::run(&big, RunOptions::default()).unwrap();
    let (t50, b50) = (tiny_res.sojourn_quantile(0.5), big_res.sojourn_quantile(0.5));
    let (t99, b99) = (tiny_res.sojourn_quantile(0.99), big_res.sojourn_quantile(0.99));
    assert!(t50 < b50, "median: tiny {t50} !< big {b50}");
    assert!(t99 < b99, "p99: tiny {t99} !< big {b99}");
}

/// The paper's Fig.-8(b) headline numbers, qualitatively: going κ=1→2
/// cuts the FJ 0.99-quantile by ≥ 20%, and κ=1→12 by ≥ 40%.
#[test]
fn fig8b_headline_reductions() {
    let l = 50usize;
    let lambda = 0.5;
    let q_at = |k: usize| {
        let mu = k as f64 / l as f64;
        let mut res = sim::run(
            &cfg(ModelKind::ForkJoinSingleQueue, l, k, lambda, mu, 40_000),
            RunOptions::default(),
        )
        .unwrap();
        res.sojourn_quantile(0.99)
    };
    let q50 = q_at(50);
    let q100 = q_at(100);
    let q600 = q_at(600);
    let r2 = 1.0 - q100 / q50;
    let r12 = 1.0 - q600 / q50;
    // Paper: 30.4% and 46.7%; allow slack for quantile noise.
    assert!(r2 > 0.20, "κ=2 reduction only {:.1}%", r2 * 100.0);
    assert!(r12 > 0.38, "κ=12 reduction only {:.1}%", r12 * 100.0);
    assert!(r12 > r2);
}

/// In-order-departure variant (the Th.-2 model) dominates the free
/// simulation sojourn-wise and both stay below the Th.-2 bound.
#[test]
fn in_order_variant_between_free_and_bound() {
    let (l, k, lambda) = (10usize, 50usize, 0.5);
    let mu = k as f64 / l as f64;
    let eps = 0.01;
    let base = cfg(ModelKind::ForkJoinSingleQueue, l, k, lambda, mu, 30_000);
    let mut free = sim::run(&base, RunOptions::default()).unwrap();
    let mut ordered = sim::run(
        &base,
        RunOptions { in_order_departures: true, ..Default::default() },
    )
    .unwrap();
    let qf = free.sojourn_quantile(1.0 - eps);
    let qo = ordered.sojourn_quantile(1.0 - eps);
    let bound = analysis::sojourn_bound(
        BoundModel::ForkJoinTiny,
        &BoundParams { l, k, lambda, mu, epsilon: eps, overhead: None },
    )
    .unwrap();
    assert!(qo >= qf, "ordering constraint can only increase sojourns");
    assert!(qo <= bound, "Th.2 bounds its own model: {qo} > {bound}");
}
