//! Trace subsystem integration: codec round-trip exactness (property
//! test over randomized traces), record → write → read → replay bitwise
//! determinism for both codecs, `Dist::Empirical` vs `stats::Ecdf`
//! agreement, and the end-to-end record → calibrate-from-trace →
//! replay pipeline of the Sec.-2.6 methodology.

use tiny_tasks::config::{ModelKind, OverheadConfig, SimulationConfig};
use tiny_tasks::dist::{parse_spec, Empirical};
use tiny_tasks::rng::{Pcg64, Rng};
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::stats::{pp_distance, Ecdf};
use tiny_tasks::trace::{
    cause, from_binary, from_ndjson, replay, to_binary, to_ndjson, JobRow, ReplayOptions,
    TaskRow, Trace, TraceFormat, TraceMeta, SCHEMA_V1, SCHEMA_V2, SCHEMA_V3,
};

fn tmp_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tt-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A randomized (but valid) trace exercising awkward float values.
/// Even seeds build v1 traces; odd seeds build v2 traces with random
/// scenario fields (speeds, replicas, loser rows); seeds ≡ 3 (mod 4)
/// upgrade to v3 with random attempt counters and failure causes, so the
/// codec property test covers all three wire formats.
fn random_trace(seed: u64) -> Trace {
    let mut rng = Pcg64::seed_from_u64(seed);
    let v2 = seed % 2 == 1;
    let v3 = seed % 4 == 3;
    let n_jobs = 1 + (rng.next_below(40) as usize);
    let k = 1 + (rng.next_below(6) as u32);
    let mut jobs = Vec::new();
    let mut tasks = Vec::new();
    let mut t = 0.0;
    for index in 0..n_jobs as u32 {
        // Mix of scales: subnormal-ish, tiny, and large magnitudes.
        t += rng.next_f64_open() * 10f64.powi(rng.next_below(7) as i32 - 3);
        let sojourn = rng.next_f64_open() * 5.0;
        jobs.push(JobRow {
            index,
            tasks: k,
            arrival: t,
            departure: t + sojourn,
            first_start: t + rng.next_f64() * 0.1,
            workload: rng.next_f64_open() * 4.0,
            task_overhead: rng.next_f64() * 1e-2,
            pre_departure_overhead: rng.next_f64() * 1e-2,
            redundant_work: 0.0,
        });
        for task in 0..k {
            let start = t + rng.next_f64();
            let dur = rng.next_f64_open();
            tasks.push(TaskRow {
                job: index,
                task,
                server: rng.next_below(8) as u32,
                start,
                end: start + dur,
                overhead: dur * rng.next_f64() * 0.1,
                // v2 rows may be cancelled replicas; v1 rows must all be
                // winners (enforced by Trace::validate).
                winner: !v2 || rng.next_below(4) != 0,
                attempt: if v3 { 1 + rng.next_below(4) as u32 } else { 1 },
                cause: if v3 { rng.next_below(u64::from(cause::MAX) + 1) as u8 } else { 0 },
            });
        }
    }
    let speeds = if v2 && rng.next_below(2) == 0 {
        Some((0..8).map(|_| 0.25 + rng.next_f64_open() * 2.0).collect())
    } else {
        None
    };
    Trace {
        meta: TraceMeta {
            schema: if v3 {
                SCHEMA_V3
            } else if v2 {
                SCHEMA_V2
            } else {
                SCHEMA_V1
            },
            source: "sim".into(),
            model: "single-queue-fork-join".into(),
            servers: 8,
            tasks_per_job: k,
            warmup: 0,
            seed: rng.next_u64(), // full u64 range: > 2^53 likely
            time_scale: 1.0,
            interarrival: "exp:0.5".into(),
            execution: "exp:1.0".into(),
            speeds,
            replicas: if v2 { 1 + rng.next_below(3) as u32 } else { 1 },
            launch_overhead: if v2 { rng.next_f64() * 1e-2 } else { 0.0 },
        },
        jobs,
        tasks,
    }
}

fn assert_bitwise_eq(a: &Trace, b: &Trace, codec: &str) {
    assert_eq!(a.meta, b.meta, "{codec}: meta");
    assert_eq!(a.jobs.len(), b.jobs.len(), "{codec}");
    assert_eq!(a.tasks.len(), b.tasks.len(), "{codec}");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.index, y.index, "{codec}");
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{codec}: job arrival bits");
        assert_eq!(x.departure.to_bits(), y.departure.to_bits(), "{codec}");
        assert_eq!(x.workload.to_bits(), y.workload.to_bits(), "{codec}");
    }
    for (x, y) in a.tasks.iter().zip(&b.tasks) {
        assert_eq!(x.start.to_bits(), y.start.to_bits(), "{codec}: task start bits");
        assert_eq!(x.end.to_bits(), y.end.to_bits(), "{codec}");
        assert_eq!(x.overhead.to_bits(), y.overhead.to_bits(), "{codec}");
        assert_eq!(x.winner, y.winner, "{codec}: winner flag");
        assert_eq!(x.attempt, y.attempt, "{codec}: attempt counter");
        assert_eq!(x.cause, y.cause, "{codec}: failure cause");
    }
}

/// Property test: 50 randomized traces round-trip bitwise through both
/// codecs, and re-encoding is byte-stable (write ∘ read = identity).
#[test]
fn codecs_round_trip_randomized_traces_exactly() {
    for seed in 0..50 {
        let tr = random_trace(seed);
        let text = to_ndjson(&tr);
        let back = from_ndjson(&text).unwrap();
        assert_bitwise_eq(&tr, &back, "ndjson");
        assert_eq!(text, to_ndjson(&back), "ndjson re-encode must be byte-stable");

        let bytes = to_binary(&tr);
        let back = from_binary(&bytes).unwrap();
        assert_bitwise_eq(&tr, &back, "binary");
        assert_eq!(bytes, to_binary(&back), "binary re-encode must be byte-stable");
    }
}

fn record_run(jobs: usize, warmup: usize, overhead: bool) -> Trace {
    let cfg = SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: 4,
        tasks_per_job: 8,
        arrival: tiny_tasks::config::ArrivalConfig { interarrival: "exp:0.3".into() },
        service: tiny_tasks::config::ServiceConfig { execution: "exp:2.0".into() },
        jobs,
        warmup,
        seed: 9,
        overhead: overhead.then(OverheadConfig::paper),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    };
    let res = sim::run(
        &cfg,
        RunOptions { record_jobs: true, trace: true, ..Default::default() },
    )
    .unwrap();
    Trace::from_sim(&res).unwrap()
}

/// The satellite acceptance: record → write → read → replay is bitwise
/// deterministic for both codecs — the two loaded copies and the
/// in-memory original all replay to identical job records.
#[test]
fn record_write_read_replay_is_bitwise_deterministic() {
    let tr = record_run(600, 60, true);
    let dir = tmp_dir();
    let nd_path = dir.join("det.ndjson");
    let bin_path = dir.join("det.bin");
    tr.write_file(&nd_path, None).unwrap();
    tr.write_file(&bin_path, None).unwrap();
    let nd = Trace::read_file(&nd_path).unwrap();
    let bin = Trace::read_file(&bin_path).unwrap();
    assert_bitwise_eq(&tr, &nd, "ndjson file");
    assert_bitwise_eq(&tr, &bin, "binary file");

    let opts = ReplayOptions {
        overhead: Some(OverheadConfig::paper()),
        seed: 4,
        ..Default::default()
    };
    let a = replay(&tr, &opts).unwrap();
    let b = replay(&nd, &opts).unwrap();
    let c = replay(&bin, &opts).unwrap();
    assert_eq!(a.jobs.len(), b.jobs.len());
    for ((x, y), z) in a.jobs.iter().zip(&b.jobs).zip(&c.jobs) {
        assert_eq!(x.departure.to_bits(), y.departure.to_bits());
        assert_eq!(x.departure.to_bits(), z.departure.to_bits());
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!(x.workload.to_bits(), z.workload.to_bits());
    }
}

/// Schema v2 end to end: a skewed + redundant run records its scenario
/// shape, survives both codecs bitwise, replays off the winner rows, and
/// keeps cancelled replicas out of the sample banks.
#[test]
fn scenario_trace_records_as_v2_and_replays() {
    let cfg = SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: 4,
        tasks_per_job: 8,
        arrival: tiny_tasks::config::ArrivalConfig { interarrival: "exp:0.3".into() },
        service: tiny_tasks::config::ServiceConfig { execution: "exp:2.0".into() },
        jobs: 300,
        warmup: 0,
        seed: 9,
        overhead: Some(OverheadConfig::paper()),
        workers: Some(tiny_tasks::config::WorkersConfig::Speeds(vec![1.5, 1.5, 0.5, 0.5])),
        redundancy: Some(tiny_tasks::config::RedundancyConfig {
            replicas: 2,
            launch_overhead: 1e-3,
        }),
        faults: None,
        policy: None,
    };
    let res = sim::run(
        &cfg,
        RunOptions { record_jobs: true, trace: true, ..Default::default() },
    )
    .unwrap();
    let tr = Trace::from_sim(&res).unwrap();
    assert_eq!(tr.meta.schema, SCHEMA_V2);
    assert_eq!(tr.meta.speeds, Some(vec![1.5, 1.5, 0.5, 0.5]));
    assert_eq!(tr.meta.replicas, 2);
    assert_eq!(tr.meta.launch_overhead, 1e-3);
    assert!(tr.tasks.iter().any(|t| !t.winner), "losers must be recorded");
    // Winner-only sample banks: one service sample per logical task.
    assert_eq!(tr.task_services().len(), 300 * 8);

    let dir = tmp_dir();
    for (name, fmt) in [("v2.ndjson", None), ("v2.bin", Some(TraceFormat::Binary))] {
        let path = dir.join(name);
        tr.write_file(&path, fmt).unwrap();
        let back = Trace::read_file(&path).unwrap();
        assert_bitwise_eq(&tr, &back, name);
        assert_eq!(back.meta.speeds, tr.meta.speeds);
    }

    // Replay resolves each logical task to its recorded winner: the
    // replayed mean sojourn lands within a scenario-sized factor of the
    // recorded one (the replay model itself is homogeneous).
    let rep = replay(&tr, &ReplayOptions::default()).unwrap();
    assert_eq!(rep.jobs.len(), 300);
    assert_eq!(rep.tasks_per_job, 8);
    let rep_mean = rep.sojourns().iter().sum::<f64>() / 300.0;
    let rec_mean = tr.sojourns().iter().sum::<f64>() / 300.0;
    assert!(
        rep_mean > 0.2 * rec_mean && rep_mean < 5.0 * rec_mean,
        "replayed mean {rep_mean} far from recorded {rec_mean}"
    );
}

/// Schema v3 end to end: a fault-injected run records attempt counters
/// and failure causes, survives both codecs bitwise, and replays off the
/// winning attempts.
#[test]
fn fault_trace_records_as_v3_and_replays() {
    let cfg = SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: 4,
        tasks_per_job: 8,
        arrival: tiny_tasks::config::ArrivalConfig { interarrival: "exp:0.3".into() },
        service: tiny_tasks::config::ServiceConfig { execution: "exp:2.0".into() },
        jobs: 200,
        warmup: 0,
        seed: 9,
        overhead: Some(OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: Some(tiny_tasks::config::FaultsConfig {
            task_fail_p: 0.25,
            max_retries: 2,
            backoff_base: 0.01,
            ..Default::default()
        }),
        policy: None,
    };
    let res = sim::run(
        &cfg,
        RunOptions { record_jobs: true, trace: true, ..Default::default() },
    )
    .unwrap();
    let tr = Trace::from_sim(&res).unwrap();
    assert_eq!(tr.meta.schema, SCHEMA_V3);
    assert!(tr.tasks.iter().any(|t| t.cause == cause::FAILED), "failures must be recorded");
    assert!(tr.tasks.iter().any(|t| t.attempt > 1), "retries must be recorded");
    // Winner-only sample banks: one service sample per logical task.
    assert_eq!(tr.task_services().len(), 200 * 8);

    let dir = tmp_dir();
    for (name, fmt) in [("v3.ndjson", None), ("v3.bin", Some(TraceFormat::Binary))] {
        let path = dir.join(name);
        tr.write_file(&path, fmt).unwrap();
        let back = Trace::read_file(&path).unwrap();
        assert_bitwise_eq(&tr, &back, name);
    }

    let rep = replay(&tr, &ReplayOptions::default()).unwrap();
    assert_eq!(rep.jobs.len(), 200);
    assert_eq!(rep.tasks_per_job, 8);
}

/// `Dist::Empirical` inverse-transform draws agree with `stats::Ecdf`
/// quantiles at the same uniforms, including when the bank is loaded
/// from a recorded trace file via the `empirical:<file>` spec.
#[test]
fn empirical_dist_matches_ecdf_quantiles() {
    let tr = record_run(300, 30, false);
    let dir = tmp_dir();
    let path = dir.join("bank.bin");
    tr.write_file(&path, Some(TraceFormat::Binary)).unwrap();
    let d = parse_spec(&format!("empirical:{}", path.display())).unwrap();
    let ecdf = Ecdf::new(tr.task_services());
    let mut a = Pcg64::seed_from_u64(33);
    let mut b = Pcg64::seed_from_u64(33);
    for _ in 0..5000 {
        let x = d.draw(&mut a);
        let u = b.next_f64_open();
        assert_eq!(x.to_bits(), ecdf.inverse(u).to_bits());
    }
    // Moments of the bank are the moments of the dist.
    let direct = Empirical::new(tr.task_services()).unwrap();
    assert_eq!(d.mean().to_bits(), direct.mean().to_bits());
    // An empirical-execution simulation runs end to end.
    let cfg = SimulationConfig {
        servers: 4,
        tasks_per_job: 8,
        arrival: tiny_tasks::config::ArrivalConfig { interarrival: "exp:0.3".into() },
        service: tiny_tasks::config::ServiceConfig {
            execution: format!("empirical:{}", path.display()),
        },
        jobs: 500,
        warmup: 50,
        ..Default::default()
    };
    let res = sim::run(&cfg, RunOptions::default()).unwrap();
    assert_eq!(res.sojourn.len(), 500);
}

/// End-to-end acceptance: a recorded fork-join trace replayed through
/// the fork-join model reproduces the recorded sojourn ECDF (PP distance
/// far below the Fig.-10 with-overhead threshold), and cross-model
/// replay stays well-defined.
#[test]
fn replay_reproduces_sojourn_ecdf_within_pp_threshold() {
    let tr = record_run(1500, 150, false);
    let rep = replay(&tr, &ReplayOptions::default()).unwrap();
    let recorded = Ecdf::new(tr.sojourns());
    let replayed = Ecdf::new(rep.sojourns());
    let d = pp_distance(&replayed, &recorded, 256);
    // Fig.-10's with-overhead fit sits around a few percent; exact
    // replay of the same model must be essentially zero.
    assert!(d < 0.02, "replay PP distance too large: {d}");
}

/// Emulator capture: wall measurements land in emulated seconds, the
/// rows are replayable, and the file round trip stays exact.
#[test]
fn emulator_capture_round_trips_and_replays() {
    let cfg = tiny_tasks::config::EmulatorConfig {
        executors: 4,
        tasks_per_job: 8,
        mode: ModelKind::ForkJoinSingleQueue,
        interarrival: "exp:2.0".into(),
        execution: "exp:2.0".into(),
        time_scale: 0.004,
        jobs: 40,
        warmup: 5,
        seed: 11,
        inject_overhead: None,
        workers: None,
    };
    let res = tiny_tasks::emulator::run(&cfg).unwrap();
    let tr = Trace::from_emulator(&res).unwrap();
    tr.validate().unwrap();
    assert_eq!(tr.meta.source, "emulator");
    assert_eq!(tr.jobs.len(), 45);
    assert_eq!(tr.tasks.len(), 45 * 8);
    // Emulated seconds: mean service should sit near E[exec] = 0.5 s,
    // not near the 2 ms wall value.
    let services = tr.task_services();
    let mean = services.iter().sum::<f64>() / services.len() as f64;
    assert!(mean > 0.2 && mean < 1.0, "service not in emulated seconds: {mean}");
    let dir = tmp_dir();
    let path = dir.join("emu.bin");
    tr.write_file(&path, None).unwrap();
    let back = Trace::read_file(&path).unwrap();
    assert_bitwise_eq(&tr, &back, "emulator binary file");
    // Replay through the recorded model: same job count, similar scale.
    let rep = replay(&back, &ReplayOptions::default()).unwrap();
    assert_eq!(rep.jobs.len(), 40);
    let rep_mean =
        rep.jobs.iter().map(|j| j.sojourn()).sum::<f64>() / rep.jobs.len() as f64;
    let rec_mean = back.sojourns().iter().sum::<f64>() / 40.0;
    assert!(
        rep_mean > 0.3 * rec_mean && rep_mean < 3.0 * rec_mean,
        "replayed mean {rep_mean} far from recorded {rec_mean}"
    );
}

/// From-trace calibration agrees with the live pipeline's acceptance:
/// parameters recovered near injected truth on the same seed, and the
/// fitted model PP-beats no-overhead. Uses a simulator-recorded trace so
/// the whole loop (record → calibrate --from-trace → replay) is
/// wall-clock cheap and deterministic.
#[test]
fn calibrate_from_trace_end_to_end() {
    let injected = OverheadConfig {
        c_task_ts: 40e-3,
        mu_task_ts: 150.0,
        c_job_pd: 0.15,
        c_task_pd: 0.0,
    };
    let cfg = SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: 4,
        tasks_per_job: 32,
        arrival: tiny_tasks::config::ArrivalConfig { interarrival: "exp:0.4".into() },
        service: tiny_tasks::config::ServiceConfig { execution: "exp:8.0".into() },
        jobs: 600,
        warmup: 60,
        seed: 7,
        overhead: Some(injected),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    };
    let res = sim::run(
        &cfg,
        RunOptions { record_jobs: true, trace: true, ..Default::default() },
    )
    .unwrap();
    let tr = Trace::from_sim(&res).unwrap();
    let dir = tmp_dir();
    let path = dir.join("calib.ndjson");
    tr.write_file(&path, None).unwrap();
    let loaded = Trace::read_file(&path).unwrap();

    let cal = tiny_tasks::coordinator::calibrate::calibrate_from_trace(&loaded).unwrap();
    assert!(
        (cal.fitted.c_task_ts - 40e-3).abs() < 15e-3,
        "c_ts={}",
        cal.fitted.c_task_ts
    );
    assert!((cal.fitted.c_job_pd - 0.15).abs() < 0.05, "c_pd={}", cal.fitted.c_job_pd);
    assert!(
        cal.pp_with_overhead < cal.pp_without_overhead,
        "PP: with={} without={}",
        cal.pp_with_overhead,
        cal.pp_without_overhead
    );

    // Replay the trace with the *fitted* model on top of the recorded
    // overhead-free task sizes: the sojourn ECDF must PP-match the
    // recorded one below the with-overhead threshold (Fig. 10 logic).
    let rep = replay(
        &loaded,
        &ReplayOptions { overhead: Some(cal.fitted), seed: 13, ..Default::default() },
    )
    .unwrap();
    let d_fitted = pp_distance(
        &Ecdf::new(rep.sojourns()),
        &Ecdf::new(loaded.sojourns()),
        256,
    );
    let rep_clean = replay(&loaded, &ReplayOptions::default()).unwrap();
    let d_clean = pp_distance(
        &Ecdf::new(rep_clean.sojourns()),
        &Ecdf::new(loaded.sojourns()),
        256,
    );
    assert!(
        d_fitted < d_clean,
        "fitted-overhead replay must fit better: {d_fitted} vs {d_clean}"
    );
    assert!(d_fitted < 0.1, "fitted replay PP distance too large: {d_fitted}");
}
