//! CLI end-to-end: every command dispatches, parses its flags, and
//! returns the documented exit codes.

use tiny_tasks::cli::Args;
use tiny_tasks::coordinator::dispatch;

fn run(argv: &[&str]) -> i32 {
    let args = Args::parse(argv.iter().map(|s| s.to_string())).unwrap();
    dispatch(&args).unwrap()
}

#[test]
fn help_and_unknown() {
    assert_eq!(run(&["help"]), 0);
    assert_eq!(run(&[]), 0);
    assert_eq!(run(&["frobnicate"]), 2);
}

#[test]
fn simulate_quick() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "2000", "--warmup", "200",
        ]),
        0
    );
}

#[test]
fn simulate_with_overhead_and_in_order() {
    assert_eq!(
        run(&[
            "simulate", "--model", "sm", "--servers", "4", "--k", "32", "--lambda", "0.3",
            "--jobs", "1000", "--warmup", "100", "--overhead", "--in-order",
        ]),
        0
    );
}

#[test]
fn simulate_from_config_file() {
    let dir = std::env::temp_dir().join(format!("tt-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "name = \"cli-test\"\n[simulation]\nmodel = \"fj\"\nservers = 4\n\
         tasks_per_job = 8\ninterarrival = \"exp:0.4\"\nexecution = \"exp:2.0\"\n\
         jobs = 500\nwarmup = 50\n",
    )
    .unwrap();
    assert_eq!(run(&["simulate", "--config", path.to_str().unwrap()]), 0);
}

#[test]
fn simulate_heterogeneous_with_redundancy() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "1000", "--warmup", "100", "--speeds", "1.5,1.5,0.5,0.5",
            "--redundancy", "2",
        ]),
        0
    );
}

#[test]
fn simulate_scenario_config_file() {
    let dir = std::env::temp_dir().join(format!("tt-cli-hetero-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("hetero.toml");
    std::fs::write(
        &path,
        "name = \"hetero\"\n[simulation]\nmodel = \"fj\"\nservers = 4\n\
         tasks_per_job = 8\ninterarrival = \"exp:0.4\"\nexecution = \"exp:2.0\"\n\
         jobs = 500\nwarmup = 50\n\
         [workers]\nspeeds = [1.5, 1.5, 0.5, 0.5]\n\
         [redundancy]\nreplicas = 2\n",
    )
    .unwrap();
    assert_eq!(run(&["simulate", "--config", path.to_str().unwrap()]), 0);
}

#[test]
fn simulate_rejects_contradictory_speed_flags() {
    let args = Args::parse(
        [
            "simulate", "--servers", "2", "--k", "4", "--speeds", "1.0,1.0",
            "--speed-dist", "uniform:0.5:1.5",
        ]
        .iter()
        .map(|s| s.to_string()),
    )
    .unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn advisor_analytic_for_skewed_cluster() {
    // Scenario flags route through the approx engine by default.
    assert_eq!(
        run(&[
            "advisor", "--servers", "4", "--lambda", "0.4", "--workload", "4",
            "--epsilon", "0.05", "--speed-dist", "uniform:0.5:1.5", "--redundancy", "2",
        ]),
        0
    );
}

#[test]
fn advisor_simulated_fallback_for_skewed_cluster() {
    assert_eq!(
        run(&[
            "advisor", "--servers", "4", "--lambda", "0.4", "--workload", "4",
            "--epsilon", "0.05", "--jobs", "1500", "--kappa-max", "8",
            "--speed-dist", "uniform:0.5:1.5", "--redundancy", "2", "--simulate=true",
        ]),
        0
    );
}

/// The `approx` command: pure analytics, CSV output, and the
/// cross-validation gate (generous window — the tight window is the CI
/// smoke job's business; this verifies the wiring and exit codes).
#[test]
fn approx_command_and_check_gate() {
    let dir = std::env::temp_dir().join(format!("tt-approx-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("approx.csv");
    assert_eq!(
        run(&[
            "approx", "--servers", "4", "--lambda", "0.4", "--workload", "4",
            "--speeds", "1.5,1.5,0.5,0.5", "--no-sim=true", "--out",
            csv.to_str().unwrap(),
        ]),
        0
    );
    let body = std::fs::read_to_string(&csv).unwrap();
    assert!(body.starts_with("k,mu,analytic_q,sim_q"), "{body}");
    assert!(body.lines().count() > 3);
    // With the sweep: the tracking gate passes inside a generous window.
    assert_eq!(
        run(&[
            "approx", "--servers", "4", "--lambda", "0.4", "--workload", "4",
            "--speeds", "1.5,1.5,0.5,0.5", "--redundancy", "2", "--k-list", "4,8,16",
            "--jobs", "1500", "--check=true", "--floor", "0.4", "--tolerance", "25",
        ]),
        0
    );
    // --check without a sweep is a usage error.
    let args = Args::parse(
        ["approx", "--servers", "4", "--no-sim=true", "--check=true"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    assert!(dispatch(&args).is_err());
    // fjps has no heterogeneous approximation.
    let args = Args::parse(
        ["approx", "--servers", "4", "--model", "fjps"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn simulate_streaming_mode() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "2000", "--warmup", "200", "--streaming=true",
        ]),
        0
    );
}

#[test]
fn bench_writes_bench_json() {
    let dir = std::env::temp_dir().join(format!("tt-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH.json");
    // --fast already selects the explicit smoke budgets; no env flips
    // (this binary's tests run in parallel).
    assert_eq!(run(&["bench", "--fast=true", "--out", path.to_str().unwrap()]), 0);
    let body = std::fs::read_to_string(&path).unwrap();
    assert!(body.contains("\"schema_version\": 1"));
    // All four models plus both calendar disciplines are present.
    for name in [
        "sim/sm/l50/k400",
        "sim/fj/l50/k400",
        "sim/fjps/l50",
        "sim/ideal/l50/k400",
        "calendar/sm/l50/k400",
        "calendar/fj/l50/k400",
        "calendar/fj/l10/k20/headline",
    ] {
        assert!(body.contains(name), "BENCH.json missing {name}:\n{body}");
    }
    assert!(body.contains("jobs_per_sec"));
    assert!(body.contains("tasks_per_sec"));
    // Sanity: it parses as a JSON object to a naive bracket check.
    assert!(body.trim_start().starts_with('{') && body.trim_end().ends_with('}'));
}

/// The full trace CLI family: record (sim source) → summarize → convert
/// (ndjson → binary) → replay → calibrate --from-trace, all through the
/// dispatcher.
#[test]
fn trace_family_end_to_end() {
    let dir = std::env::temp_dir().join(format!("tt-cli-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let nd = dir.join("t.ndjson");
    let bin = dir.join("t.bin");
    assert_eq!(
        run(&[
            "trace", "record", "--source", "sim", "--model", "fj", "--servers", "4",
            "--k", "8", "--lambda", "0.4", "--jobs", "500", "--warmup", "50",
            "--overhead", "--out", nd.to_str().unwrap(),
        ]),
        0
    );
    assert!(nd.exists());
    assert_eq!(run(&["trace", "summarize", "--in", nd.to_str().unwrap()]), 0);
    assert_eq!(
        run(&[
            "trace", "convert", "--in", nd.to_str().unwrap(), "--out",
            bin.to_str().unwrap(),
        ]),
        0
    );
    assert!(bin.exists());
    // Binary is the compact codec: strictly smaller than the NDJSON.
    assert!(
        std::fs::metadata(&bin).unwrap().len() < std::fs::metadata(&nd).unwrap().len()
    );
    // Replay the binary copy through a different model.
    assert_eq!(
        run(&[
            "trace", "replay", "--in", bin.to_str().unwrap(), "--model", "sm",
        ]),
        0
    );
    // Offline calibration against the recorded file.
    assert_eq!(run(&["calibrate", "--from-trace", nd.to_str().unwrap()]), 0);
    // An empirical execution spec drawn from the trace drives simulate.
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda",
            "0.3", "--jobs", "1000", "--warmup", "100", "--execution",
            &format!("empirical:{}", bin.display()),
        ]),
        0
    );
}

#[test]
fn trace_subcommand_errors_are_clean() {
    for argv in [
        vec!["trace"],
        vec!["trace", "frob"],
        vec!["trace", "replay"],
        vec!["trace", "convert", "--in", "/no/such/trace.ndjson"],
        vec!["calibrate", "--from-trace", "/no/such/trace.ndjson"],
    ] {
        let args = Args::parse(argv.iter().map(|s| s.to_string())).unwrap();
        assert!(dispatch(&args).is_err(), "{argv:?} should error");
    }
}

/// Scenario runs record as schema v2 through the CLI and flow through
/// summarize, convert, replay, and calibrate — the workflows that used
/// to reject `--speeds`/`--redundancy` at `trace record`.
#[test]
fn trace_record_scenario_as_v2() {
    let dir = std::env::temp_dir().join(format!("tt-cli-trace-v2-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let nd = dir.join("v2.ndjson");
    let bin = dir.join("v2.bin");
    assert_eq!(
        run(&[
            "trace", "record", "--source", "sim", "--model", "fj", "--servers", "4",
            "--k", "8", "--lambda", "0.4", "--jobs", "300", "--warmup", "30",
            "--overhead", "--speeds", "1.5,1.5,0.5,0.5", "--redundancy", "2",
            "--replica-launch", "0.001", "--out", nd.to_str().unwrap(),
        ]),
        0
    );
    let tr = tiny_tasks::trace::Trace::read_file(&nd).unwrap();
    assert_eq!(tr.meta.schema, tiny_tasks::trace::SCHEMA_V2);
    assert_eq!(tr.meta.replicas, 2);
    assert_eq!(tr.meta.speeds, Some(vec![1.5, 1.5, 0.5, 0.5]));
    assert_eq!(tr.meta.launch_overhead, 0.001);
    assert_eq!(run(&["trace", "summarize", "--in", nd.to_str().unwrap()]), 0);
    assert_eq!(
        run(&["trace", "convert", "--in", nd.to_str().unwrap(), "--out", bin.to_str().unwrap()]),
        0
    );
    assert_eq!(run(&["trace", "replay", "--in", bin.to_str().unwrap()]), 0);
    assert_eq!(run(&["calibrate", "--from-trace", bin.to_str().unwrap()]), 0);
}

#[test]
fn emulate_with_pinned_slow_executors() {
    assert_eq!(
        run(&[
            "emulate", "--executors", "2", "--k", "4", "--mode", "fj", "--jobs", "20",
            "--warmup", "2", "--time-scale", "0.004", "--speeds", "1.0,0.5",
        ]),
        0
    );
    // Speedups are rejected for the emulator (real payloads).
    let args = Args::parse(
        ["emulate", "--executors", "2", "--k", "4", "--speeds", "1.0,2.0"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn bench_baseline_gate() {
    let dir = std::env::temp_dir().join(format!("tt-bench-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH.json");
    let baseline = dir.join("BASE.json");
    // A permissive baseline passes...
    std::fs::write(
        &baseline,
        "{\n  \"entries\": [\n    {\"name\": \"calendar/fj/l10/k20/headline\", \
         \"jobs_per_sec\": 1}\n  ]\n}\n",
    )
    .unwrap();
    assert_eq!(
        run(&[
            "bench", "--fast=true", "--out", out.to_str().unwrap(), "--baseline",
            baseline.to_str().unwrap(),
        ]),
        0
    );
    // ...an absurdly high baseline fails with exit code 1.
    std::fs::write(
        &baseline,
        "{\n  \"entries\": [\n    {\"name\": \"calendar/fj/l10/k20/headline\", \
         \"jobs_per_sec\": 1e18}\n  ]\n}\n",
    )
    .unwrap();
    assert_eq!(
        run(&[
            "bench", "--fast=true", "--out", out.to_str().unwrap(), "--baseline",
            baseline.to_str().unwrap(),
        ]),
        1
    );
}

#[test]
fn emulate_quick() {
    assert_eq!(
        run(&[
            "emulate", "--executors", "3", "--k", "6", "--mode", "fj", "--jobs", "30",
            "--warmup", "3", "--time-scale", "0.004",
        ]),
        0
    );
}

#[test]
fn bounds_native_engine() {
    assert_eq!(
        run(&[
            "bounds", "--engine", "rust", "--servers", "20", "--k", "100", "--lambda",
            "0.4", "--epsilon", "0.001",
        ]),
        0
    );
    // Big-tasks variant.
    assert_eq!(
        run(&[
            "bounds", "--engine", "rust", "--model", "sm-big", "--servers", "5", "--k",
            "5", "--kappa", "10", "--lambda", "0.4", "--mu", "10",
        ]),
        0
    );
}

#[test]
fn stability_scan() {
    assert_eq!(
        run(&["stability", "--servers", "10", "--k-list", "10,40,160"]),
        0
    );
}

#[test]
fn advisor_native() {
    assert_eq!(
        run(&[
            "advisor", "--servers", "10", "--lambda", "0.5", "--workload", "10",
        ]),
        0
    );
}

#[test]
fn figure_rejects_unknown_id() {
    let args = Args::parse(["figure", "figXX"].iter().map(|s| s.to_string())).unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn figure_requires_id() {
    let args = Args::parse(["figure"].iter().map(|s| s.to_string())).unwrap();
    assert!(dispatch(&args).is_err());
}
