//! CLI end-to-end: every command dispatches, parses its flags, and
//! returns the documented exit codes.

use tiny_tasks::cli::Args;
use tiny_tasks::coordinator::dispatch;

fn run(argv: &[&str]) -> i32 {
    let args = Args::parse(argv.iter().map(|s| s.to_string())).unwrap();
    dispatch(&args).unwrap()
}

#[test]
fn help_and_unknown() {
    assert_eq!(run(&["help"]), 0);
    assert_eq!(run(&[]), 0);
    assert_eq!(run(&["frobnicate"]), 2);
}

#[test]
fn simulate_quick() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "2000", "--warmup", "200",
        ]),
        0
    );
}

#[test]
fn simulate_with_overhead_and_in_order() {
    assert_eq!(
        run(&[
            "simulate", "--model", "sm", "--servers", "4", "--k", "32", "--lambda", "0.3",
            "--jobs", "1000", "--warmup", "100", "--overhead", "--in-order",
        ]),
        0
    );
}

#[test]
fn simulate_from_config_file() {
    let dir = std::env::temp_dir().join(format!("tt-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "name = \"cli-test\"\n[simulation]\nmodel = \"fj\"\nservers = 4\n\
         tasks_per_job = 8\ninterarrival = \"exp:0.4\"\nexecution = \"exp:2.0\"\n\
         jobs = 500\nwarmup = 50\n",
    )
    .unwrap();
    assert_eq!(run(&["simulate", "--config", path.to_str().unwrap()]), 0);
}

#[test]
fn simulate_heterogeneous_with_redundancy() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "1000", "--warmup", "100", "--speeds", "1.5,1.5,0.5,0.5",
            "--redundancy", "2",
        ]),
        0
    );
}

#[test]
fn simulate_scenario_config_file() {
    let dir = std::env::temp_dir().join(format!("tt-cli-hetero-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("hetero.toml");
    std::fs::write(
        &path,
        "name = \"hetero\"\n[simulation]\nmodel = \"fj\"\nservers = 4\n\
         tasks_per_job = 8\ninterarrival = \"exp:0.4\"\nexecution = \"exp:2.0\"\n\
         jobs = 500\nwarmup = 50\n\
         [workers]\nspeeds = [1.5, 1.5, 0.5, 0.5]\n\
         [redundancy]\nreplicas = 2\n",
    )
    .unwrap();
    assert_eq!(run(&["simulate", "--config", path.to_str().unwrap()]), 0);
}

#[test]
fn simulate_rejects_contradictory_speed_flags() {
    let args = Args::parse(
        [
            "simulate", "--servers", "2", "--k", "4", "--speeds", "1.0,1.0",
            "--speed-dist", "uniform:0.5:1.5",
        ]
        .iter()
        .map(|s| s.to_string()),
    )
    .unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn advisor_simulated_for_skewed_cluster() {
    assert_eq!(
        run(&[
            "advisor", "--servers", "4", "--lambda", "0.4", "--workload", "4",
            "--epsilon", "0.05", "--jobs", "1500", "--kappa-max", "8",
            "--speed-dist", "uniform:0.5:1.5", "--redundancy", "2",
        ]),
        0
    );
}

#[test]
fn simulate_streaming_mode() {
    assert_eq!(
        run(&[
            "simulate", "--model", "fj", "--servers", "4", "--k", "8", "--lambda", "0.4",
            "--jobs", "2000", "--warmup", "200", "--streaming=true",
        ]),
        0
    );
}

#[test]
fn bench_writes_bench_json() {
    let dir = std::env::temp_dir().join(format!("tt-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH.json");
    // --fast already selects the explicit smoke budgets; no env flips
    // (this binary's tests run in parallel).
    assert_eq!(run(&["bench", "--fast=true", "--out", path.to_str().unwrap()]), 0);
    let body = std::fs::read_to_string(&path).unwrap();
    assert!(body.contains("\"schema_version\": 1"));
    // All four models plus both calendar disciplines are present.
    for name in [
        "sim/sm/l50/k400",
        "sim/fj/l50/k400",
        "sim/fjps/l50",
        "sim/ideal/l50/k400",
        "calendar/sm/l50/k400",
        "calendar/fj/l50/k400",
        "calendar/fj/l10/k20/headline",
    ] {
        assert!(body.contains(name), "BENCH.json missing {name}:\n{body}");
    }
    assert!(body.contains("jobs_per_sec"));
    assert!(body.contains("tasks_per_sec"));
    // Sanity: it parses as a JSON object to a naive bracket check.
    assert!(body.trim_start().starts_with('{') && body.trim_end().ends_with('}'));
}

#[test]
fn emulate_quick() {
    assert_eq!(
        run(&[
            "emulate", "--executors", "3", "--k", "6", "--mode", "fj", "--jobs", "30",
            "--warmup", "3", "--time-scale", "0.004",
        ]),
        0
    );
}

#[test]
fn bounds_native_engine() {
    assert_eq!(
        run(&[
            "bounds", "--engine", "rust", "--servers", "20", "--k", "100", "--lambda",
            "0.4", "--epsilon", "0.001",
        ]),
        0
    );
    // Big-tasks variant.
    assert_eq!(
        run(&[
            "bounds", "--engine", "rust", "--model", "sm-big", "--servers", "5", "--k",
            "5", "--kappa", "10", "--lambda", "0.4", "--mu", "10",
        ]),
        0
    );
}

#[test]
fn stability_scan() {
    assert_eq!(
        run(&["stability", "--servers", "10", "--k-list", "10,40,160"]),
        0
    );
}

#[test]
fn advisor_native() {
    assert_eq!(
        run(&[
            "advisor", "--servers", "10", "--lambda", "0.5", "--workload", "10",
        ]),
        0
    );
}

#[test]
fn figure_rejects_unknown_id() {
    let args = Args::parse(["figure", "figXX"].iter().map(|s| s.to_string())).unwrap();
    assert!(dispatch(&args).is_err());
}

#[test]
fn figure_requires_id() {
    let args = Args::parse(["figure"].iter().map(|s| s.to_string())).unwrap();
    assert!(dispatch(&args).is_err());
}
