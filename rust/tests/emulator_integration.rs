//! sparklite ⟷ simulator consistency: the emulator (real threads, real
//! serialization, scaled wall-clock) and the DES (virtual time) must tell
//! the same statistical story — the premise of the Sec.-2.6 calibration.

use tiny_tasks::config::{
    ArrivalConfig, EmulatorConfig, ModelKind, OverheadConfig, ServiceConfig, SimulationConfig,
};
use tiny_tasks::emulator;
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::stats::{pp_distance, Ecdf};

fn emu_cfg(mode: ModelKind, jobs: usize) -> EmulatorConfig {
    EmulatorConfig {
        executors: 4,
        tasks_per_job: 16,
        mode,
        interarrival: "exp:0.5".into(),
        execution: "exp:4.0".into(), // mean 0.25 s emulated per task
        time_scale: 0.01,            // 100x speedup
        jobs,
        warmup: jobs / 10,
        seed: 21,
        inject_overhead: None,
        workers: None,
    }
}

fn sim_cfg_from(e: &EmulatorConfig, jobs: usize) -> SimulationConfig {
    SimulationConfig {
        model: e.mode,
        servers: e.executors,
        tasks_per_job: e.tasks_per_job,
        arrival: ArrivalConfig { interarrival: e.interarrival.clone() },
        service: ServiceConfig { execution: e.execution.clone() },
        jobs,
        warmup: jobs / 10,
        seed: 99,
        overhead: None,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// Fork-join: emulated and simulated sojourn distributions PP-match
/// (the emulator's intrinsic overhead is ≪ the 0.25 s tasks).
#[test]
fn fj_emulator_matches_simulator_distribution() {
    let ecfg = emu_cfg(ModelKind::ForkJoinSingleQueue, 250);
    let eres = emulator::run(&ecfg).unwrap();
    let emu = Ecdf::new(eres.measured_jobs().map(|j| j.sojourn()).collect());
    let sres = sim::run(
        &sim_cfg_from(&ecfg, 20_000),
        RunOptions { record_jobs: true, ..Default::default() },
    )
    .unwrap();
    let sim = Ecdf::new(sres.jobs.iter().map(|j| j.sojourn()).collect());
    let d = pp_distance(&sim, &emu, 200);
    assert!(d < 0.12, "PP distance too large: {d}");
}

/// Split-merge mode matches too, including the blocking barrier.
#[test]
fn sm_emulator_matches_simulator_distribution() {
    // κ = 4 at utilization 0.5: stable for l = 4 (ρ* ≈ 0.785).
    let ecfg = emu_cfg(ModelKind::SplitMerge, 250);
    let eres = emulator::run(&ecfg).unwrap();
    let emu = Ecdf::new(eres.measured_jobs().map(|j| j.sojourn()).collect());
    let sres = sim::run(
        &sim_cfg_from(&ecfg, 20_000),
        RunOptions { record_jobs: true, ..Default::default() },
    )
    .unwrap();
    let sim = Ecdf::new(sres.jobs.iter().map(|j| j.sojourn()).collect());
    let d = pp_distance(&sim, &emu, 200);
    assert!(d < 0.15, "PP distance too large: {d}");
}

/// Injected overhead moves the emulator exactly the way the DES overhead
/// model moves the simulator (the Fig.-10 logic, inverted).
#[test]
fn injected_overhead_matches_des_overhead_model() {
    let oh = OverheadConfig {
        c_task_ts: 0.05, // 50 ms per 250 ms task: 20% — clearly visible
        mu_task_ts: f64::INFINITY,
        c_job_pd: 0.1,
        c_task_pd: 0.0,
    };
    let mut ecfg = emu_cfg(ModelKind::ForkJoinSingleQueue, 250);
    ecfg.inject_overhead = Some(oh);
    let eres = emulator::run(&ecfg).unwrap();
    let emu = Ecdf::new(eres.measured_jobs().map(|j| j.sojourn()).collect());

    let mut scfg = sim_cfg_from(&ecfg, 20_000);
    scfg.overhead = Some(oh);
    let sres = sim::run(&scfg, RunOptions { record_jobs: true, ..Default::default() }).unwrap();
    let sim_oh = Ecdf::new(sres.jobs.iter().map(|j| j.sojourn()).collect());

    let mut scfg_clean = sim_cfg_from(&ecfg, 20_000);
    scfg_clean.overhead = None;
    let sres_clean =
        sim::run(&scfg_clean, RunOptions { record_jobs: true, ..Default::default() }).unwrap();
    let sim_clean = Ecdf::new(sres_clean.jobs.iter().map(|j| j.sojourn()).collect());

    let d_with = pp_distance(&sim_oh, &emu, 200);
    let d_without = pp_distance(&sim_clean, &emu, 200);
    assert!(
        d_with < d_without,
        "overhead model should fit better: with={d_with} without={d_without}"
    );
    assert!(d_with < 0.12, "residual mismatch too large: {d_with}");
}

/// Task-count and executor-id sanity across the full emulator stack.
#[test]
fn emulator_accounting() {
    let ecfg = emu_cfg(ModelKind::ForkJoinSingleQueue, 60);
    let res = emulator::run(&ecfg).unwrap();
    let total = ecfg.jobs + ecfg.warmup;
    assert_eq!(res.listener.jobs.len(), total);
    assert_eq!(res.listener.tasks.len(), total * ecfg.tasks_per_job);
    for t in &res.listener.tasks {
        assert!((t.executor_id as usize) < ecfg.executors);
        assert!(t.occupancy >= t.execution);
        assert!(t.execution > 0.0);
    }
    // Every executor did work (FIFO queue serves all).
    let mut seen = vec![false; ecfg.executors];
    for t in &res.listener.tasks {
        seen[t.executor_id as usize] = true;
    }
    assert!(seen.iter().all(|&s| s));
}
