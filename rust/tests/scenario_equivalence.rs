//! Degenerate-scenario equivalence regressions: the heterogeneous /
//! redundant machinery must collapse *exactly* onto the homogeneous
//! models when its knobs are neutral, and the devirtualized exponential
//! fast path must be a pure refactor.
//!
//! These are bit-for-bit (`assert_eq!` on f64) — not tolerance — tests:
//! the scenario dispatcher divides by speed 1.0 and takes a 1-replica
//! minimum, both of which are exact identities in IEEE-754.

use tiny_tasks::config::{
    ArrivalConfig, ModelKind, RedundancyConfig, ServiceConfig, SimulationConfig, WorkersConfig,
};
use tiny_tasks::sim::{self, RunOptions};

fn base(model: ModelKind, l: usize, k: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.4".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs: 4_000,
        warmup: 400,
        seed: 2024,
        overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

fn quantiles(cfg: &SimulationConfig) -> (Vec<f64>, f64, f64) {
    let mut res = sim::run(cfg, RunOptions::default()).unwrap();
    let qs = [0.1, 0.5, 0.9, 0.99]
        .iter()
        .map(|&q| res.sojourn_quantile(q))
        .collect();
    (qs, res.sojourn_summary.mean(), res.waiting_quantile(0.9))
}

/// Speeds all 1.0 and r = 1 reproduce the homogeneous sojourn quantiles
/// exactly, for every model.
#[test]
fn unit_speeds_r1_is_bitwise_homogeneous() {
    for (model, l, k) in [
        (ModelKind::SplitMerge, 5, 25),
        (ModelKind::ForkJoinSingleQueue, 5, 25),
        (ModelKind::ForkJoinPerServer, 5, 5),
        (ModelKind::Ideal, 5, 25),
    ] {
        let homogeneous = base(model, l, k);
        let degenerate = SimulationConfig {
            workers: Some(WorkersConfig::Speeds(vec![1.0; l])),
            redundancy: Some(RedundancyConfig::new(1)),
            ..base(model, l, k)
        };
        let (qa, ma, wa) = quantiles(&homogeneous);
        let (qb, mb, wb) = quantiles(&degenerate);
        assert_eq!(qa, qb, "{model}: sojourn quantiles diverge");
        assert_eq!(ma, mb, "{model}: sojourn mean diverges");
        assert_eq!(wa, wb, "{model}: waiting quantile diverges");
    }
}

/// The same holds without overhead (the branch-light hot path).
#[test]
fn unit_speeds_r1_is_bitwise_homogeneous_no_overhead() {
    for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
        let mut homogeneous = base(model, 4, 16);
        homogeneous.overhead = None;
        let degenerate = SimulationConfig {
            workers: Some(WorkersConfig::Speeds(vec![1.0; 4])),
            redundancy: Some(RedundancyConfig::new(1)),
            ..homogeneous.clone()
        };
        let (qa, ma, _) = quantiles(&homogeneous);
        let (qb, mb, _) = quantiles(&degenerate);
        assert_eq!(qa, qb, "{model}");
        assert_eq!(ma, mb, "{model}");
    }
}

/// `TT_NO_FAST_EXP=1` (dyn-dispatch sampling) matches the devirtualized
/// exponential fast path bit-for-bit: same RNG stream, same formula —
/// both for the homogeneous path and for a skewed + redundant scenario
/// (which samples through the same `Workload`).
///
/// Both comparisons live in ONE test so the env-var set/remove cannot
/// interleave with itself across test threads and silently compare
/// slow-vs-slow. The env var is read at `Workload` construction; other
/// tests in this binary that race with the flipped var would only take
/// the slow path, whose equivalence is exactly what is proven here.
#[test]
fn no_fast_exp_env_matches_fast_path_bitwise() {
    let homogeneous = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let scenario = SimulationConfig {
        workers: Some(WorkersConfig::Speeds(vec![1.5, 1.5, 1.0, 0.5, 0.5])),
        redundancy: Some(RedundancyConfig::new(2)),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    assert!(std::env::var_os("TT_NO_FAST_EXP").is_none(), "leaked env var");
    let (qa, ma, wa) = quantiles(&homogeneous);
    let (sa, sma, _) = quantiles(&scenario);
    std::env::set_var("TT_NO_FAST_EXP", "1");
    let (qb, mb, wb) = quantiles(&homogeneous);
    let (sb, smb, _) = quantiles(&scenario);
    std::env::remove_var("TT_NO_FAST_EXP");
    assert_eq!(qa, qb, "sojourn quantiles diverge without the fast path");
    assert_eq!(ma, mb);
    assert_eq!(wa, wb);
    assert_eq!(sa, sb, "scenario path diverges without the fast path");
    assert_eq!(sma, smb);
}

/// Non-degenerate scenarios genuinely change the law (guards against the
/// scenario plumbing silently not reaching the models).
#[test]
fn skewed_scenario_changes_the_distribution() {
    let homogeneous = base(ModelKind::ForkJoinSingleQueue, 4, 16);
    let skewed = SimulationConfig {
        workers: Some(WorkersConfig::Speeds(vec![1.9, 1.9, 0.1, 0.1])),
        ..homogeneous.clone()
    };
    let (qa, _, _) = quantiles(&homogeneous);
    let (qb, _, _) = quantiles(&skewed);
    assert_ne!(qa, qb, "skewed speeds must alter sojourn quantiles");
    // Strong skew at fixed capacity hurts the tail.
    assert!(qb[3] > qa[3], "p99 should degrade under skew: {} vs {}", qb[3], qa[3]);
}
