//! Obs-layer invariants (the metrics & profiling tentpole).
//!
//! The hard contract: the registry consumes NO RNG draws and never
//! branches on collected values, so a run with `metrics: true` is
//! **bitwise identical** to the same run with metrics off — for every
//! model and every composed feature axis (overhead, scenario, faults,
//! policy). On top of that: sharded registries merge in shard-index
//! order (thread count unobservable), the RUN_METRICS.json report
//! round-trips, and counters reconcile exactly with a recorded trace.

use tiny_tasks::config::{
    ArrivalConfig, FaultsConfig, ModelKind, OverheadConfig, PolicyConfig, PolicyKind,
    RedundancyConfig, ServiceConfig, SimulationConfig, WorkersConfig,
};
use tiny_tasks::obs::{report, Counter, Phase};
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::trace::{cause, Trace};

fn base(model: ModelKind, l: usize, k: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.4".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs: 2_000,
        warmup: 200,
        seed: 99,
        overhead: Some(OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// Every feature-composed config the runner accepts, one per axis.
fn composed_configs() -> Vec<(&'static str, SimulationConfig)> {
    vec![
        ("sm/plain", base(ModelKind::SplitMerge, 5, 25)),
        (
            "fj/faults",
            SimulationConfig {
                faults: Some(FaultsConfig {
                    mtbf: 60.0,
                    mttr: 1.0,
                    task_fail_p: 0.04,
                    backoff_base: 0.01,
                    ..FaultsConfig::default()
                }),
                ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
            },
        ),
        (
            "fj/scenario",
            SimulationConfig {
                workers: Some(WorkersConfig::Speeds(vec![1.5, 1.5, 1.0, 0.5, 0.5])),
                redundancy: Some(RedundancyConfig::new(2)),
                ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
            },
        ),
        (
            "fj/policy",
            SimulationConfig {
                policy: Some(PolicyConfig {
                    kind: PolicyKind::Priority,
                    classes: 2,
                    ..PolicyConfig::default()
                }),
                ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
            },
        ),
        ("fjps/plain", base(ModelKind::ForkJoinPerServer, 5, 5)),
        ("ideal/plain", base(ModelKind::Ideal, 5, 25)),
    ]
}

/// Collecting metrics never perturbs results: every statistic the off
/// run produces, the on run reproduces bit for bit, across all four
/// models with scenario/faults/policy composed in.
#[test]
fn metrics_on_is_bitwise_identical_for_every_model() {
    for (name, cfg) in composed_configs() {
        let mut off = sim::run(&cfg, RunOptions::default()).unwrap();
        let mut on = sim::run(&cfg, RunOptions { metrics: true, ..Default::default() }).unwrap();
        assert!(!off.metrics.is_enabled(), "{name}: off run carries a registry");
        assert!(on.metrics.is_enabled(), "{name}: on run lost its registry");
        assert_eq!(off.sojourn_summary.mean(), on.sojourn_summary.mean(), "{name}");
        assert_eq!(off.sojourn_summary.variance(), on.sojourn_summary.variance(), "{name}");
        assert_eq!(off.sojourn_summary.min(), on.sojourn_summary.min(), "{name}");
        assert_eq!(off.sojourn_summary.max(), on.sojourn_summary.max(), "{name}");
        assert_eq!(off.overhead_summary.mean(), on.overhead_summary.mean(), "{name}");
        assert_eq!(off.redundant_summary.mean(), on.redundant_summary.mean(), "{name}");
        assert_eq!(off.lost_summary.mean(), on.lost_summary.mean(), "{name}");
        assert_eq!(off.retry_summary.mean(), on.retry_summary.mean(), "{name}");
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(off.sojourn_quantile(q), on.sojourn_quantile(q), "{name} q={q}");
            assert_eq!(off.waiting_quantile(q), on.waiting_quantile(q), "{name} q={q}");
        }
        // The engines' tallies populate: every run completes its jobs
        // (warmup included — the engines cannot tell them apart) and
        // dispatches k logical tasks per job.
        let total = (cfg.jobs + cfg.warmup) as u64;
        let m = &on.metrics;
        assert_eq!(m.counter(Counter::JobsCompleted), total, "{name}");
        assert_eq!(
            m.counter(Counter::TasksDispatched),
            total * cfg.tasks_per_job as u64,
            "{name}"
        );
        assert!(
            m.counter(Counter::ExecutionDraws) >= m.counter(Counter::TasksDispatched),
            "{name}"
        );
        assert_eq!(m.sojourn_hist.total(), cfg.jobs as u64, "{name}");
        assert!(m.phase_seconds(Phase::Dispatch) > 0.0, "{name}");
        match name {
            "fj/faults" => assert!(m.counter(Counter::Retries) > 0, "{name}: no retries tallied"),
            "fj/scenario" => {
                assert!(m.counter(Counter::ReplicaLosers) > 0, "{name}: no losers tallied")
            }
            _ => {}
        }
    }
}

/// One interarrival draw per job on the plain path; heap pushes balance
/// pops on the recursion engine's server heap.
#[test]
fn draw_and_heap_counters_reconcile() {
    let cfg = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let res = sim::run(&cfg, RunOptions { metrics: true, ..Default::default() }).unwrap();
    let m = &res.metrics;
    let total = (cfg.jobs + cfg.warmup) as u64;
    assert_eq!(m.counter(Counter::ArrivalDraws), total);
    assert_eq!(m.counter(Counter::ExecutionDraws), total * cfg.tasks_per_job as u64);
    assert_eq!(m.counter(Counter::HeapPushes), m.counter(Counter::HeapPops));
}

/// Sharded runs merge per-shard registries in shard-index order: the
/// thread count is unobservable bit for bit, and the merged counters
/// account for every shard's jobs (each shard runs its own warmup).
#[test]
fn sharded_registries_merge_deterministically() {
    let cfg = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let shards = 3usize;
    let serial = sim::run(
        &cfg,
        RunOptions { shards, threads: 1, metrics: true, ..Default::default() },
    )
    .unwrap();
    let parallel = sim::run(
        &cfg,
        RunOptions { shards, threads: 3, metrics: true, ..Default::default() },
    )
    .unwrap();
    for c in Counter::ALL {
        assert_eq!(
            serial.metrics.counter(c),
            parallel.metrics.counter(c),
            "thread count changed counter {}",
            c.key()
        );
    }
    assert_eq!(serial.metrics.sojourn_hist.counts(), parallel.metrics.sojourn_hist.counts());
    let total = (cfg.jobs + shards * cfg.warmup) as u64;
    assert_eq!(serial.metrics.counter(Counter::JobsCompleted), total);
    assert_eq!(
        serial.metrics.counter(Counter::TasksDispatched),
        total * cfg.tasks_per_job as u64
    );
    // Only the measured jobs land in the latency histogram.
    assert_eq!(serial.metrics.sojourn_hist.total(), cfg.jobs as u64);
    // And the merged run is still bitwise the metrics-off sharded run.
    let mut off = sim::run(&cfg, RunOptions { shards, threads: 2, ..Default::default() }).unwrap();
    let mut on = sim::run(
        &cfg,
        RunOptions { shards, threads: 2, metrics: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(off.sojourn_summary.mean(), on.sojourn_summary.mean());
    assert_eq!(off.sojourn_quantile(0.99), on.sojourn_quantile(0.99));
}

/// RUN_METRICS.json round-trips through a real run: render → parse
/// reproduces every counter, phase, and throughput figure.
#[test]
fn run_metrics_report_round_trips() {
    let cfg = base(ModelKind::SplitMerge, 5, 25);
    let res = sim::run(&cfg, RunOptions { metrics: true, ..Default::default() }).unwrap();
    let text = report::render("simulate", &res.metrics, cfg.jobs as u64, res.wall_seconds);
    let rep = report::parse(&text).unwrap();
    assert_eq!(rep.schema_version, report::SCHEMA_VERSION);
    assert_eq!(rep.source, "simulate");
    for c in Counter::ALL {
        assert_eq!(rep.counters[c.key()], res.metrics.counter(c), "{}", c.key());
    }
    for p in Phase::ALL {
        assert_eq!(rep.phases[p.key()], res.metrics.phase_seconds(p), "{}", p.key());
    }
    assert_eq!(rep.jobs, cfg.jobs as u64);
    assert_eq!(rep.wall_seconds, res.wall_seconds);
    assert_eq!(rep.sojourn_hist.iter().sum::<u64>(), cfg.jobs as u64);
}

/// Counters reconcile exactly with a recorded trace: one task row per
/// dispatched task on the plain path; with per-attempt failures, one
/// extra FAILED row per tallied retry.
#[test]
fn counters_reconcile_with_recorded_trace() {
    let opts = RunOptions { record_jobs: true, trace: true, metrics: true, ..Default::default() };

    let plain = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let res = sim::run(&plain, opts).unwrap();
    let trace = Trace::from_sim(&res).unwrap();
    assert_eq!(trace.tasks.len() as u64, res.metrics.counter(Counter::TasksDispatched));

    let faulty = SimulationConfig {
        faults: Some(FaultsConfig {
            task_fail_p: 0.05,
            max_retries: 3,
            backoff_base: 0.01,
            ..FaultsConfig::default()
        }),
        ..base(ModelKind::ForkJoinSingleQueue, 5, 25)
    };
    let res = sim::run(&faulty, opts).unwrap();
    let trace = Trace::from_sim(&res).unwrap();
    let retries = res.metrics.counter(Counter::Retries);
    assert!(retries > 0, "fault config produced no retries");
    // Every attempt leaves a row: the success per task plus one FAILED
    // row per retried attempt.
    assert_eq!(
        trace.tasks.len() as u64,
        res.metrics.counter(Counter::TasksDispatched) + retries
    );
    let failed_rows =
        trace.tasks.iter().filter(|t| t.cause == cause::FAILED).count() as u64;
    assert_eq!(failed_rows, retries);
}

/// The span profiler obeys the same hard contract as the registry:
/// profiling on is bit-for-bit profiling off, across the calendar
/// engine's model/faults/policy matrix — and the span enter counts
/// reconcile exactly with the engine's raw tallies.
#[test]
fn calendar_span_profile_is_bitwise_inert_and_reconciles() {
    use tiny_tasks::dist::Exponential;
    use tiny_tasks::obs::Span;
    use tiny_tasks::sim::{
        Calendar, Discipline, FaultInjector, OverheadModel, TraceLog, Workload,
    };

    let fault_cfg = FaultsConfig {
        mtbf: 8.0,
        mttr: 0.5,
        task_fail_p: 0.05,
        backoff_base: 0.02,
        ..FaultsConfig::default()
    };
    let sita = PolicyConfig {
        kind: PolicyKind::Sita,
        sita_boundaries: vec![0.5],
        ..PolicyConfig::default()
    };
    let steal = PolicyConfig {
        kind: PolicyKind::WorkSteal,
        steal_threshold: 0.25,
        ..PolicyConfig::default()
    };
    type Build = Box<dyn Fn() -> Calendar>;
    let cases: Vec<(&str, Build)> = vec![
        (
            "fj/plain",
            Box::new(|| Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![8])),
        ),
        ("sm/stages", Box::new(|| Calendar::new(Discipline::SplitMerge, 4, vec![6, 2]))),
        (
            "fj/faults",
            Box::new(move || {
                Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![8])
                    .with_faults(Some(FaultInjector::new(fault_cfg, 4, 17, 1.0)))
            }),
        ),
        (
            "fj/sita",
            Box::new(move || {
                Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![8])
                    .with_policy(Some(&sita))
            }),
        ),
        (
            "fj/steal",
            Box::new(move || {
                Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![8])
                    .with_policy(Some(&steal))
            }),
        ),
    ];
    for (name, build) in cases {
        let mk_w =
            || Workload::new(Exponential::new(0.35).into(), Exponential::new(2.0).into(), 23);
        let oh = OverheadModel::paper_default();
        let mut tr = TraceLog::disabled();
        let mut off = build();
        let a = off.run(400, &mut mk_w(), &oh, &mut tr);
        let mut on = build().with_profile(true);
        let b = on.run(400, &mut mk_w(), &oh, &mut tr);
        assert!(off.spans().is_empty(), "{name}: unprofiled run recorded spans");
        assert_eq!(a.len(), b.len(), "{name}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival, "{name}");
            assert_eq!(x.departure, y.departure, "{name}");
            assert_eq!(x.first_start, y.first_start, "{name}");
            assert_eq!(x.workload, y.workload, "{name}");
            assert_eq!(x.task_overhead, y.task_overhead, "{name}");
            assert_eq!(x.lost_work, y.lost_work, "{name}");
            assert_eq!(x.redundant_work, y.redundant_work, "{name}");
            assert_eq!(x.retries, y.retries, "{name}");
        }
        let t = on.tallies();
        let s = on.spans();
        assert_eq!(s.count(Span::EventLoop), 1, "{name}");
        assert_eq!(s.count(Span::HeapPop), t.events, "{name}");
        assert_eq!(s.count(Span::Dispatch), t.events, "{name}");
        let kind_sum = s.count(Span::Arrival)
            + s.count(Span::Finish)
            + s.count(Span::Departure)
            + s.count(Span::Fault)
            + s.count(Span::StealTick);
        assert_eq!(kind_sum, t.events, "{name}: every event lands in exactly one kind span");
        assert_eq!(s.count(Span::Arrival), 400, "{name}: one arrival event per job");
    }
}

/// Schema v2 adds percentiles, span maps, and dropped-sample tallies as
/// trailing keys: a real run's report carries monotone percentiles and
/// zero dropped samples, and the (span-less) recursion engines still
/// serialize the full span key set at zero.
#[test]
fn report_v2_surfaces_percentiles_spans_and_dropped_samples() {
    let cfg = base(ModelKind::ForkJoinSingleQueue, 5, 25);
    let res = sim::run(&cfg, RunOptions { metrics: true, ..Default::default() }).unwrap();
    let text = report::render("simulate", &res.metrics, cfg.jobs as u64, res.wall_seconds);
    let rep = report::parse(&text).unwrap();
    assert_eq!(rep.schema_version, 2);
    assert_eq!(rep.percentiles.len(), 8, "4 quantiles x (sojourn, waiting)");
    let p = |k: &str| rep.percentiles[k];
    assert!(p("sojourn_p50") > 0.0);
    assert!(p("sojourn_p50") <= p("sojourn_p90"));
    assert!(p("sojourn_p90") <= p("sojourn_p99"));
    assert!(p("sojourn_p99") <= p("sojourn_p999"));
    assert!(p("waiting_p50") <= p("waiting_p999"));
    assert_eq!(rep.dropped_samples["sojourn_seconds"], 0);
    assert_eq!(rep.dropped_samples["waiting_seconds"], 0);
    // The recursion engines have no event loop: the span maps still
    // serialize every key, all zero.
    assert_eq!(rep.span_counts.len(), rep.span_seconds.len());
    assert_eq!(rep.span_counts["event_loop"], 0);
    assert!(rep.span_counts.values().all(|&n| n == 0));
}

/// End-to-end regression gating: `profile --diff --gate` exits non-zero
/// when the new report's gated phase degrades past the ratio, and 0
/// when the allowance covers it.
#[test]
fn profile_diff_gate_exits_nonzero_on_degraded_phase() {
    use std::collections::BTreeMap;
    use tiny_tasks::cli::Args;
    use tiny_tasks::coordinator::commands;
    use tiny_tasks::obs::Metrics;

    let dir = std::env::temp_dir();
    let base_path = dir.join(format!("tt_obs_diff_base_{}.json", std::process::id()));
    let new_path = dir.join(format!("tt_obs_diff_new_{}.json", std::process::id()));
    let mut mb = Metrics::enabled();
    mb.phase_add_secs(Phase::Dispatch, 1.0);
    let mut mn = Metrics::enabled();
    mn.phase_add_secs(Phase::Dispatch, 3.0);
    std::fs::write(&base_path, report::render("profile", &mb, 100, 2.0)).unwrap();
    std::fs::write(&new_path, report::render("profile", &mn, 100, 2.0)).unwrap();
    let run = |gate: &str| {
        let mut flags = BTreeMap::new();
        flags.insert("diff".to_string(), base_path.display().to_string());
        flags.insert("gate".to_string(), gate.to_string());
        let args = Args {
            command: "profile".into(),
            positional: vec![new_path.display().to_string()],
            flags,
        };
        commands::cmd_profile(&args).unwrap()
    };
    assert_eq!(run("dispatch:1.5"), 1, "3x dispatch must trip a 1.5x gate");
    assert_eq!(run("dispatch:4.0"), 0, "a 4x allowance passes");
    assert_eq!(run("no_such_row:2.0"), 1, "unknown rows fail closed");
    let _ = std::fs::remove_file(&base_path);
    let _ = std::fs::remove_file(&new_path);
}
