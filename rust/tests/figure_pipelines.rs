//! Figure-pipeline integration: run the cheap pipelines end to end into
//! a temp directory and check the CSVs exist and carry the paper's
//! qualitative shapes.

use std::fs;
use std::path::PathBuf;
use tiny_tasks::coordinator::figures::{self, FigureCtx, Scale};
use tiny_tasks::runtime::BoundsEngine;
use tiny_tasks::util::threadpool::ThreadPool;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tt-figtest-{tag}-{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn read_csv(path: &PathBuf) -> Vec<Vec<f64>> {
    let text = fs::read_to_string(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    text.lines()
        .skip(1)
        .map(|line| {
            line.split(',')
                .map(|c| c.parse::<f64>().unwrap_or(f64::NAN))
                .collect()
        })
        .collect()
}

#[test]
fn fig13_shape_fj_decreasing_above_ideal() {
    let dir = tmp_dir("fig13");
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::new(2);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    figures::fig13(&ctx).unwrap();
    let rows = read_csv(&dir.join("fig13_bounds.csv"));
    assert!(rows.len() >= 5);
    // fork_join column decreases with k and stays above ideal.
    for w in rows.windows(2) {
        assert!(w[1][1] < w[0][1], "fj not decreasing: {w:?}");
    }
    for r in &rows {
        assert!(r[1] > r[3], "fj below ideal: {r:?}");
        // split-merge, when feasible, sits above fork-join.
        if !r[2].is_nan() {
            assert!(r[2] > r[1], "sm below fj: {r:?}");
        }
    }
    // Small k: split-merge infeasible (NaN); large k: feasible.
    assert!(rows[0][2].is_nan());
    assert!(!rows.last().unwrap()[2].is_nan());
}

#[test]
fn fig12a_tiny_dominates_big_and_decays() {
    let dir = tmp_dir("fig12a");
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::new(2);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    figures::fig12a(&ctx).unwrap();
    let rows = read_csv(&dir.join("fig12a_stability.csv"));
    for r in &rows {
        let (l, tiny, big) = (r[0], r[1], r[2]);
        if l > 1.5 {
            assert!(tiny > big, "l={l}: tiny {tiny} !> big {big}");
        }
        assert!((0.0..=1.0 + 1e-9).contains(&tiny));
        assert!((0.0..=1.0 + 1e-9).contains(&big));
    }
    // Big-tasks region decays with l; tiny stays high (κ=20).
    let first = &rows[0];
    let last = rows.last().unwrap();
    assert!(last[2] < first[2]);
    assert!(last[1] > 0.75, "tiny region should stay high: {}", last[1]);
}

#[test]
fn fig11_stability_csv_shapes() {
    let dir = tmp_dir("fig11");
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::new(2);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    figures::fig11(&ctx).unwrap();
    let rows = read_csv(&dir.join("fig11_stability.csv"));
    // Columns: k, sm_no, sm_oh, fj_no, fj_oh, eq20.
    for r in &rows {
        assert!(r[2] <= r[1] + 0.02, "overhead must not improve SM: {r:?}");
        assert!((r[3] - 1.0).abs() < 1e-9, "clean FJ stability is 1");
        assert!(r[4] < 1.0, "FJ overhead strictly below 1");
        // Monte-Carlo SM (clean) tracks Eq. 20 within a few percent.
        assert!((r[1] - r[5]).abs() / r[5] < 0.05, "MC vs Eq20: {r:?}");
    }
    // SM-with-overhead rises then falls (peak interior) at quick scale.
    let oh: Vec<f64> = rows.iter().map(|r| r[2]).collect();
    let peak = oh.iter().cloned().fold(0.0f64, f64::max);
    assert!(peak > oh[0] && peak > *oh.last().unwrap(), "no interior peak: {oh:?}");
}

#[test]
fn fig1_2_traces_written() {
    let dir = tmp_dir("fig12gantt");
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::new(2);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    figures::fig1_2(&ctx).unwrap();
    let fig1 = read_csv(&dir.join("fig1_gantt.csv"));
    let fig2 = read_csv(&dir.join("fig2_gantt.csv"));
    assert_eq!(fig1.len(), 4 * 400, "fig1: one row per task");
    assert_eq!(fig2.len(), 4 * 1500, "fig2: one row per task");
}

/// The hetero-approx acceptance: the analytic approximation tracks the
/// simulated sojourn quantiles across two skewed-speed configurations
/// and one redundancy configuration.
#[test]
fn hetero_approx_panel_tracks_simulation() {
    let dir = tmp_dir("hetapprox");
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::new(2);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    figures::fig_hetero_approx(&ctx).unwrap();
    // Columns: config (label, NaN to the f64 reader), skew, replicas, k,
    // analytic_q, sim_q.
    let rows = read_csv(&dir.join("hetero_approx_panel.csv"));
    assert_eq!(rows.len(), 3 * 5, "3 configs x 5 ks at quick scale");
    let mut compared = 0usize;
    for r in &rows {
        let (analytic, sim) = (r[4], r[5]);
        assert!(sim.is_finite() && sim > 0.0, "bad simulated quantile: {r:?}");
        if analytic.is_nan() {
            continue; // approximation infeasible at this point
        }
        compared += 1;
        let ratio = analytic / sim;
        assert!(
            (0.4..=25.0).contains(&ratio),
            "approximation far from simulation (ratio {ratio}): {r:?}"
        );
    }
    assert!(compared >= 12, "too few comparable points: {compared}");
}

#[test]
fn unknown_figure_id_is_an_error() {
    let dir = tmp_dir("bad");
    let engine = BoundsEngine::native();
    let pool = ThreadPool::new(1);
    let ctx = FigureCtx { out_dir: &dir, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };
    assert!(figures::run("fig99", &ctx).is_err());
}
