//! Streaming-vs-exact runner equivalence: the O(1)-memory mode (P²
//! quantile bank + Welford summaries, no sample storage) must leave the
//! simulation itself untouched — bitwise-equal means, sample counts, and
//! per-third summaries — and estimate quantiles within P² tolerance.

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::coordinator::sweep::{run_sweep, run_sweep_with, SweepOptions, SweepPoint};
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::util::threadpool::ThreadPool;

fn cfg(model: ModelKind, l: usize, k: usize, jobs: usize, seed: u64) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.4".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs,
        warmup: jobs / 10,
        seed,
        overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// Same seed, both memory modes, every model: bitwise-equal streaming
/// summaries, quantiles within tolerance.
#[test]
fn streaming_runner_equivalent_to_exact() {
    for (model, k) in [
        (ModelKind::SplitMerge, 32),
        (ModelKind::ForkJoinSingleQueue, 32),
        (ModelKind::ForkJoinPerServer, 8),
        (ModelKind::Ideal, 32),
    ] {
        let c = cfg(model, 8, k, 30_000, 5);
        let mut exact = sim::run(&c, RunOptions::default()).unwrap();
        let mut stream = sim::run(
            &c,
            RunOptions { streaming: true, streaming_q: Some(0.8), ..Default::default() },
        )
        .unwrap();
        // The sample stream is identical, so the exact accumulators are
        // bitwise equal.
        assert_eq!(exact.sojourn_summary.mean(), stream.sojourn_summary.mean(), "{model}: mean");
        assert_eq!(
            exact.sojourn_summary.variance(),
            stream.sojourn_summary.variance(),
            "{model}: variance"
        );
        assert_eq!(exact.overhead_summary.mean(), stream.overhead_summary.mean());
        assert_eq!(exact.sojourn.len(), stream.sojourn.len(), "{model}: count");
        for i in 0..3 {
            assert_eq!(
                exact.thirds[i].count(),
                stream.thirds[i].count(),
                "{model}: third {i} count"
            );
            assert_eq!(exact.thirds[i].mean(), stream.thirds[i].mean(), "{model}: third {i} mean");
        }
        // P² tracks the exact quantiles within a few percent at 30k
        // samples (default grid + the explicitly registered 0.8).
        for q in [0.5, 0.8, 0.9, 0.99] {
            let (a, b) = (exact.sojourn_quantile(q), stream.sojourn_quantile(q));
            assert!((a - b).abs() / a < 0.15, "{model} q={q}: exact {a} vs P2 {b}");
        }
        // Combined abs+rel tolerance: low-load waiting quantiles can be
        // exactly 0 in the exact sketch while P² interpolates near 0.
        let (a, b) = (exact.waiting_quantile(0.9), stream.waiting_quantile(0.9));
        assert!((a - b).abs() <= 0.15 * a + 0.05, "{model} waiting: {a} vs {b}");
    }
}

/// Streaming mode records no per-job samples unless asked to.
#[test]
fn streaming_mode_stores_no_jobs() {
    let c = cfg(ModelKind::ForkJoinSingleQueue, 8, 32, 5_000, 9);
    let mut res = sim::run(&c, RunOptions { streaming: true, ..Default::default() }).unwrap();
    assert!(res.jobs.is_empty());
    assert_eq!(res.sojourn.len(), 5_000);
    assert!(res.sojourn.as_exact_mut().is_none(), "streaming must not store samples");
}

/// The sweep layer threads streaming through to every point: bitwise
/// means, tolerant quantiles, pool-size independence preserved.
#[test]
fn streaming_sweep_equivalent_and_pool_independent() {
    let mk = |k: usize| SweepPoint {
        label: k as f64,
        config: cfg(ModelKind::ForkJoinSingleQueue, 8, k, 12_000, 0),
    };
    let points: Vec<SweepPoint> = [16, 32, 64].iter().map(|&k| mk(k)).collect();
    let opts = SweepOptions { q: 0.99, streaming: true };
    let pool1 = ThreadPool::new(1);
    let pool4 = ThreadPool::new(4);
    let s1 = run_sweep_with(&pool1, points.clone(), opts, 7).unwrap();
    let s4 = run_sweep_with(&pool4, points.clone(), opts, 7).unwrap();
    for (a, b) in s1.iter().zip(&s4) {
        assert_eq!(a.sojourn_q, b.sojourn_q, "pool-size dependence");
        assert_eq!(a.sojourn_mean, b.sojourn_mean);
    }
    let exact = run_sweep(&pool4, points, 0.99, 7).unwrap();
    for (a, b) in exact.iter().zip(&s1) {
        assert_eq!(a.sojourn_mean, b.sojourn_mean, "k={}", a.label);
        assert!(
            (a.sojourn_q - b.sojourn_q).abs() / a.sojourn_q < 0.2,
            "k={}: exact {} vs P2 {}",
            a.label,
            a.sojourn_q,
            b.sojourn_q
        );
    }
}
