//! Degeneracy property tests for the `approx` subsystem: with all
//! worker speeds exactly 1.0 and replicas = 1, every approximation must
//! equal the homogeneous `analysis::{stability, theorem1, theorem2}`
//! output **bit for bit** — the delegation contract that makes `approx`
//! a strict superset of the paper's analysis rather than a parallel
//! implementation that could drift.
//!
//! Randomized mini-quickcheck style (as in `property_invariants.rs`):
//! parameters are drawn from a seeded PCG stream, assertions are
//! `to_bits` equality, not tolerance.

use tiny_tasks::analysis::{self, BoundModel, BoundParams};
use tiny_tasks::approx::{self, ApproxModel, ClusterSpec};
use tiny_tasks::config::{ModelKind, OverheadConfig};
use tiny_tasks::coordinator::advisor;
use tiny_tasks::rng::{Pcg64, Rng};
use tiny_tasks::runtime::BoundsEngine;

/// 200 random (l, k) pairs: degenerate stability equals Eq. 20 / the
/// fork-join constant bitwise.
#[test]
fn stability_degenerates_bitwise() {
    let mut rng = Pcg64::seed_from_u64(41);
    for _ in 0..200 {
        let l = 1 + rng.next_below(64) as usize;
        let k = l * (1 + rng.next_below(40) as usize);
        let spec = ClusterSpec::homogeneous(l);
        assert_eq!(
            approx::sm_max_utilization(&spec, k).to_bits(),
            analysis::stability::sm_tiny_tasks(l, k).to_bits(),
            "sm stability diverges at l={l}, k={k}"
        );
        assert_eq!(
            approx::fork_join_max_utilization(&spec).to_bits(),
            analysis::stability::fork_join().to_bits(),
            "fj stability diverges at l={l}"
        );
    }
}

/// 60 random parameter sets × 2 models × overhead on/off: degenerate
/// sojourn and waiting approximations equal the Theorem-1/2 bounds
/// bitwise, including infeasibility (None) agreement.
#[test]
fn bounds_degenerate_bitwise() {
    let mut rng = Pcg64::seed_from_u64(42);
    for round in 0..60 {
        let l = 1 + rng.next_below(32) as usize;
        let k = l * (1 + rng.next_below(30) as usize);
        let lambda = 0.05 + rng.next_f64_open();
        // Mix stable and overloaded regimes: μ from well below to well
        // above the k·λ/l stability edge.
        let mu = (k as f64 / l as f64) * (0.2 + 2.0 * rng.next_f64_open());
        let epsilon = 10f64.powi(-(1 + rng.next_below(6) as i32));
        let overhead = if round % 2 == 0 { None } else { Some(OverheadConfig::paper()) };
        let spec = ClusterSpec::homogeneous(l);
        let p = approx::ApproxParams { k, lambda, mu, epsilon, overhead };
        let bp = BoundParams { l, k, lambda, mu, epsilon, overhead };
        for (am, bm) in [
            (ApproxModel::ForkJoin, BoundModel::ForkJoinTiny),
            (ApproxModel::SplitMerge, BoundModel::SplitMergeTiny),
        ] {
            assert_eq!(
                approx::sojourn_quantile(am, &spec, &p).map(f64::to_bits),
                analysis::sojourn_bound(bm, &bp).map(f64::to_bits),
                "{am:?} sojourn diverges at l={l} k={k} lambda={lambda} mu={mu} \
                 eps={epsilon} overhead={}",
                overhead.is_some()
            );
            assert_eq!(
                approx::waiting_quantile(am, &spec, &p).map(f64::to_bits),
                analysis::waiting_bound(bm, &bp).map(f64::to_bits),
                "{am:?} waiting diverges at l={l} k={k} lambda={lambda} mu={mu}"
            );
        }
    }
}

/// The advisor pick: the degenerate analytic scenario advisor returns
/// the homogeneous advisor's curve and recommendation bitwise, for both
/// tiny-tasks models and several cluster sizes.
#[test]
fn advisor_pick_degenerates_bitwise() {
    let engine = BoundsEngine::native();
    for l in [5usize, 16, 50] {
        for model in [ModelKind::ForkJoinSingleQueue, ModelKind::SplitMerge] {
            let reference = advisor::recommend(
                &engine,
                model,
                l,
                0.5,
                l as f64,
                0.01,
                OverheadConfig::paper(),
            )
            .unwrap();
            let approx_rec = advisor::recommend_approx(
                model,
                &ClusterSpec::homogeneous(l),
                0.5,
                l as f64,
                0.01,
                OverheadConfig::paper(),
                200.0,
            )
            .unwrap();
            assert_eq!(reference.curve.len(), approx_rec.curve.len(), "{model} l={l}");
            for ((ka, ta), (kb, tb)) in reference.curve.iter().zip(&approx_rec.curve) {
                assert_eq!(ka, kb);
                assert_eq!(
                    ta.map(f64::to_bits),
                    tb.map(f64::to_bits),
                    "{model} l={l} k={ka}: advisor curve diverges"
                );
            }
            assert_eq!(
                reference.best.map(|(k, t)| (k, t.to_bits())),
                approx_rec.best.map(|(k, t)| (k, t.to_bits())),
                "{model} l={l}: advisor pick diverges"
            );
        }
    }
}

/// Guard against silent delegation-everywhere: non-degenerate scenarios
/// must actually change the answer (the approx layer is not a no-op).
#[test]
fn non_degenerate_scenarios_change_answers() {
    let l = 10usize;
    let k = 80usize;
    let mu = k as f64 / l as f64;
    let p = approx::ApproxParams {
        k,
        lambda: 0.4,
        mu,
        epsilon: 0.01,
        overhead: Some(OverheadConfig::paper()),
    };
    let flat = ClusterSpec::homogeneous(l);
    let mut speeds = vec![1.5; l / 2];
    speeds.extend(vec![0.5; l / 2]);
    let skewed = ClusterSpec::new(speeds, 1, 0.0).unwrap();
    for model in [ApproxModel::ForkJoin, ApproxModel::SplitMerge] {
        let a = approx::sojourn_quantile(model, &flat, &p).unwrap();
        let b = approx::sojourn_quantile(model, &skewed, &p).unwrap();
        assert_ne!(a.to_bits(), b.to_bits(), "{model:?}: skew must change the bound");
        assert!(b > a, "{model:?}: skew at equal capacity must hurt: {b} !> {a}");
    }
    assert!(
        approx::sm_max_utilization(&skewed, k) < approx::sm_max_utilization(&flat, k),
        "skew must shrink the split-merge stability region"
    );
}
