//! Property tests (mini-quickcheck) on simulator/coordinator invariants —
//! the DESIGN.md §6 list: work conservation, departure ordering,
//! task-count conservation, trace consistency.

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::util::quickcheck::{check, Config};

fn random_config(g: &mut tiny_tasks::util::quickcheck::Gen, model: ModelKind) -> SimulationConfig {
    let l = g.usize_range(1, 20);
    let kappa = g.usize_range(1, 8);
    let k = if model == ModelKind::ForkJoinPerServer { l } else { l * kappa };
    let lambda = g.f64_range(0.05, 0.8);
    let mu = k as f64 / l as f64;
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
        service: ServiceConfig { execution: format!("exp:{mu}") },
        jobs: 300,
        warmup: 0,
        seed: g.u64_range(0, u64::MAX - 1),
        overhead: if g.bool_with(0.5) {
            Some(tiny_tasks::config::OverheadConfig::paper())
        } else {
            None
        },
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// Split-merge: departures are FIFO, jobs never overlap in service, and
/// each job's sojourn ≥ its workload / l.
#[test]
fn prop_split_merge_serialization() {
    check(
        Config { cases: 24, seed: 0xA11CE },
        |g| random_config(g, ModelKind::SplitMerge),
        |cfg| {
            let res = sim::run(cfg, RunOptions { record_jobs: true, ..Default::default() })
                .map_err(|e| e.to_string())?;
            let mut prev_departure = 0.0f64;
            for j in &res.jobs {
                if j.departure < prev_departure - 1e-9 {
                    return Err(format!("departure order violated at job {}", j.index));
                }
                if j.first_start < prev_departure - 1e-9 {
                    return Err(format!("job {} started before predecessor departed", j.index));
                }
                let min_service = j.workload / cfg.servers as f64;
                if j.service_time() < min_service - 1e-9 {
                    return Err(format!(
                        "job {} served faster than perfectly parallel: {} < {}",
                        j.index,
                        j.service_time(),
                        min_service
                    ));
                }
                prev_departure = j.departure;
            }
            Ok(())
        },
    );
}

/// Every model: departure ≥ arrival + (max single contribution), task
/// counts conserved, sojourn = departure − arrival ≥ 0.
#[test]
fn prop_basic_accounting_all_models() {
    for model in [
        ModelKind::SplitMerge,
        ModelKind::ForkJoinSingleQueue,
        ModelKind::ForkJoinPerServer,
        ModelKind::Ideal,
    ] {
        check(
            Config { cases: 12, seed: 0xB0B + model as u64 },
            |g| random_config(g, model),
            |cfg| {
                let res = sim::run(cfg, RunOptions { record_jobs: true, ..Default::default() })
                    .map_err(|e| e.to_string())?;
                if res.jobs.len() != cfg.jobs {
                    return Err(format!("job count {} != {}", res.jobs.len(), cfg.jobs));
                }
                for j in &res.jobs {
                    if j.sojourn() <= 0.0 {
                        return Err(format!("non-positive sojourn at job {}", j.index));
                    }
                    if j.workload <= 0.0 {
                        return Err("non-positive workload".into());
                    }
                    if j.waiting() > j.sojourn() + 1e-9 {
                        return Err("waiting exceeds sojourn".into());
                    }
                }
                Ok(())
            },
        );
    }
}

/// Trace consistency (FJ + SM): per-server intervals never overlap, and
/// per-job task counts match k (work conservation at the trace level).
#[test]
fn prop_trace_consistency() {
    for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
        check(
            Config { cases: 10, seed: 0x7234CE },
            |g| {
                let mut cfg = random_config(g, model);
                cfg.jobs = 40; // traces are memory-heavy
                cfg
            },
            |cfg| {
                let res = sim::run(
                    cfg,
                    RunOptions { trace: true, record_jobs: true, ..Default::default() },
                )
                .map_err(|e| e.to_string())?;
                // Group events per server, check non-overlap.
                let mut per_server: Vec<Vec<(f64, f64)>> = vec![Vec::new(); cfg.servers];
                let mut per_job: Vec<usize> = vec![0; cfg.jobs];
                for ev in res.trace.events() {
                    per_server[ev.server as usize].push((ev.start, ev.end));
                    per_job[ev.job as usize] += 1;
                    if ev.end < ev.start {
                        return Err("event ends before it starts".into());
                    }
                }
                for (s, intervals) in per_server.iter_mut().enumerate() {
                    intervals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    for w in intervals.windows(2) {
                        if w[1].0 < w[0].1 - 1e-9 {
                            return Err(format!("server {s} runs two tasks at once"));
                        }
                    }
                }
                for (job, &count) in per_job.iter().enumerate() {
                    if count != cfg.tasks_per_job {
                        return Err(format!(
                            "job {job} ran {count} tasks, expected {}",
                            cfg.tasks_per_job
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Work conservation for the single-queue fork-join model: with a
/// saturating backlog, total busy time across servers equals the total
/// workload (no idling while work is queued).
#[test]
fn prop_work_conservation_under_saturation() {
    check(
        Config { cases: 12, seed: 0x5A7 },
        |g| {
            let l = g.usize_range(2, 12);
            let k = l * g.usize_range(1, 6);
            (l, k, g.u64_range(0, 1 << 40))
        },
        |&(l, k, seed)| {
            let cfg = SimulationConfig {
                model: ModelKind::ForkJoinSingleQueue,
                servers: l,
                tasks_per_job: k,
                // Arrivals far faster than service: permanent backlog.
                arrival: ArrivalConfig { interarrival: "det:0.0001".into() },
                service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
                jobs: 60,
                warmup: 0,
                seed,
                overhead: None,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            };
            let res = sim::run(
                &cfg,
                RunOptions { trace: true, record_jobs: true, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let total_work: f64 = res.jobs.iter().map(|j| j.workload).sum();
            let makespan = res
                .jobs
                .iter()
                .map(|j| j.departure)
                .fold(0.0f64, f64::max);
            // Ignore the tail ramp-down: check utilization over the busy
            // window via the trace.
            let busy: f64 = res
                .trace
                .utilization(l, 0.1 * makespan, 0.9 * makespan)
                .iter()
                .sum::<f64>()
                / l as f64;
            if busy < 0.999 {
                return Err(format!("idle under saturation: busy={busy}"));
            }
            if total_work <= 0.0 {
                return Err("no work".into());
            }
            Ok(())
        },
    );
}
