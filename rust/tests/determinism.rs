//! Determinism harness: sweep results must be a pure function of
//! (points, quantile, master seed) — independent of thread-pool size,
//! scheduling order, and repeated invocation. This is what makes every
//! figure in the repo reproducible from its seed.

use tiny_tasks::config::{
    ArrivalConfig, ModelKind, RedundancyConfig, ServiceConfig, SimulationConfig, WorkersConfig,
};
use tiny_tasks::coordinator::sweep::{run_sweep, SweepOutcome, SweepPoint};
use tiny_tasks::util::threadpool::ThreadPool;

fn point(model: ModelKind, k: usize, jobs: usize) -> SweepPoint {
    SweepPoint {
        label: k as f64,
        config: SimulationConfig {
            model,
            servers: 10,
            tasks_per_job: k,
            arrival: ArrivalConfig { interarrival: "exp:0.5".into() },
            service: ServiceConfig { execution: format!("exp:{}", k as f64 / 10.0) },
            jobs,
            warmup: jobs / 10,
            seed: 0, // reseeded per point from the master seed
            overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        },
    }
}

/// The deterministic fields of a sweep row (jobs_per_sec is wall-clock
/// telemetry and legitimately varies).
fn deterministic_fields(o: &SweepOutcome) -> (f64, f64, f64, f64, f64) {
    (o.label, o.sojourn_q, o.sojourn_mean, o.overhead_mean, o.redundant_mean)
}

fn assert_identical(a: &[SweepOutcome], b: &[SweepOutcome], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: row count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(
            deterministic_fields(x),
            deterministic_fields(y),
            "{tag}: row for k={} diverges",
            x.label
        );
    }
}

/// `run_sweep` over the same points with `ThreadPool::new(1)` and
/// `ThreadPool::new(8)` yields identical rows: per-point seeding really
/// is pool-size independent.
#[test]
fn sweep_rows_identical_across_pool_sizes() {
    let mk_points = || -> Vec<SweepPoint> {
        let mut pts = Vec::new();
        for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
            for k in [10usize, 30, 80] {
                pts.push(point(model, k, 2_500));
            }
        }
        pts
    };
    let pool1 = ThreadPool::new(1);
    let pool8 = ThreadPool::new(8);
    let a = run_sweep(&pool1, mk_points(), 0.99, 0xD5EED).unwrap();
    let b = run_sweep(&pool8, mk_points(), 0.99, 0xD5EED).unwrap();
    assert_identical(&a, &b, "pool 1 vs 8");

    // And re-running on the same pool reproduces the rows (no hidden
    // global state).
    let c = run_sweep(&pool8, mk_points(), 0.99, 0xD5EED).unwrap();
    assert_identical(&b, &c, "rerun on pool 8");
}

/// Pool-size independence extends to heterogeneous + redundant points —
/// the scenario dispatcher draws from the per-point stream only.
#[test]
fn scenario_sweep_rows_identical_across_pool_sizes() {
    let mk_points = || -> Vec<SweepPoint> {
        [20usize, 60]
            .iter()
            .map(|&k| {
                let mut p = point(ModelKind::ForkJoinSingleQueue, k, 2_000);
                p.config.workers = Some(WorkersConfig::Distribution {
                    spec: "uniform:0.5:1.5".into(),
                    seed: 5,
                });
                p.config.redundancy = Some(RedundancyConfig::new(2));
                p
            })
            .collect()
    };
    let pool1 = ThreadPool::new(1);
    let pool8 = ThreadPool::new(8);
    let a = run_sweep(&pool1, mk_points(), 0.95, 77).unwrap();
    let b = run_sweep(&pool8, mk_points(), 0.95, 77).unwrap();
    assert_identical(&a, &b, "scenario pool 1 vs 8");
    assert!(a.iter().all(|o| o.redundant_mean > 0.0));
}

/// Different master seeds give different rows (the reseeding is live).
#[test]
fn master_seed_actually_reseeds() {
    let pool = ThreadPool::new(4);
    let a = run_sweep(
        &pool,
        vec![point(ModelKind::ForkJoinSingleQueue, 20, 2_000)],
        0.99,
        1,
    )
    .unwrap();
    let b = run_sweep(
        &pool,
        vec![point(ModelKind::ForkJoinSingleQueue, 20, 2_000)],
        0.99,
        2,
    )
    .unwrap();
    assert_ne!(a[0].sojourn_q, b[0].sojourn_q);
}
