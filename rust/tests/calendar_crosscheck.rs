//! Cross-validation of the two independent simulator implementations:
//! the per-job recursion engines (`sim::models`) and the event-calendar
//! engine (`sim::calendar`). Structural agreement between independently
//! written simulators is the strongest correctness evidence we can get
//! without the original forkulator.
//!
//! The calendar engine draws each job's tasks at its arrival event and
//! schedules arrivals lazily, so its RNG draw order is identical to the
//! recursion engines' (arrival, then k × (execution, overhead), per job
//! in arrival order). For single-stage workloads the cross-check is
//! therefore **bit-for-bit** — including with the overhead model enabled
//! and at k not divisible by l — not merely distributional.

use tiny_tasks::config::OverheadConfig;
use tiny_tasks::dist::{Deterministic, Exponential};
use tiny_tasks::sim::models::{ForkJoinSingleQueue, Model, SplitMerge};
use tiny_tasks::sim::{Calendar, Discipline, JobRecord, OverheadModel, TraceLog, Workload};

fn mk_workload(lambda: f64, mu: f64, seed: u64) -> Workload {
    Workload::new(Exponential::new(lambda).into(), Exponential::new(mu).into(), seed)
}

/// Drive a recursion-engine model through `n` jobs, mirroring the
/// public runner's loop.
fn run_recursion<M: Model>(
    model: &mut M,
    n: usize,
    workload: &mut Workload,
    overhead: &OverheadModel,
) -> Vec<JobRecord> {
    let mut tr = TraceLog::disabled();
    (0..n)
        .map(|j| {
            let a = workload.next_arrival();
            model.advance(j, a, workload, overhead, &mut tr)
        })
        .collect()
}

fn assert_bitwise_equal(rec: &[JobRecord], cal: &[JobRecord], tag: &str) {
    assert_eq!(rec.len(), cal.len(), "{tag}: record counts");
    for (j, (a, b)) in rec.iter().zip(cal).enumerate() {
        assert!(a.arrival == b.arrival, "{tag} job {j}: arrival {} vs {}", a.arrival, b.arrival);
        assert!(
            a.departure == b.departure,
            "{tag} job {j}: departure {} vs {}",
            a.departure,
            b.departure
        );
        assert!(
            a.workload == b.workload,
            "{tag} job {j}: workload {} vs {}",
            a.workload,
            b.workload
        );
        assert!(
            a.task_overhead == b.task_overhead,
            "{tag} job {j}: overhead {} vs {}",
            a.task_overhead,
            b.task_overhead
        );
        assert!(
            a.pre_departure_overhead == b.pre_departure_overhead,
            "{tag} job {j}: pre-departure {} vs {}",
            a.pre_departure_overhead,
            b.pre_departure_overhead
        );
    }
}

/// Single-queue fork-join: identical seeds ⇒ identical records, bitwise.
#[test]
fn fj_engines_agree_bitwise() {
    for &(l, k, lambda, seed) in &[
        (2usize, 6usize, 0.4, 11u64),
        (10, 40, 0.5, 12),
        (25, 25, 0.3, 13),
        (5, 50, 0.6, 14),
        (7, 25, 0.45, 15), // k not divisible by l
    ] {
        let mu = k as f64 / l as f64;
        let n = 2000;
        let oh = OverheadModel::none();
        let mut w1 = mk_workload(lambda, mu, seed);
        let mut model = ForkJoinSingleQueue::new(l, k);
        let rec = run_recursion(&mut model, n, &mut w1, &oh);
        let mut w2 = mk_workload(lambda, mu, seed);
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32]);
        let mut tr = TraceLog::disabled();
        let cal_recs = cal.run(n, &mut w2, &oh, &mut tr);
        assert_bitwise_equal(&rec, &cal_recs, &format!("fj l={l} k={k}"));
    }
}

/// Fork-join with the paper's overhead model (an extra exponential draw
/// per task, deterministic pre-departure): still bitwise-identical, at a
/// k not divisible by l.
#[test]
fn fj_engines_agree_bitwise_with_overhead() {
    let (l, k, lambda, seed) = (7usize, 25usize, 0.45, 21u64);
    let mu = k as f64 / l as f64;
    let n = 1500;
    let oh = OverheadModel::new(OverheadConfig::paper());
    let mut w1 = mk_workload(lambda, mu, seed);
    let mut model = ForkJoinSingleQueue::new(l, k);
    let rec = run_recursion(&mut model, n, &mut w1, &oh);
    let mut w2 = mk_workload(lambda, mu, seed);
    let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32]);
    let mut tr = TraceLog::disabled();
    let cal_recs = cal.run(n, &mut w2, &oh, &mut tr);
    assert_bitwise_equal(&rec, &cal_recs, "fj+overhead");
    // The overhead model genuinely fired.
    assert!(rec.iter().all(|r| r.task_overhead > 0.0));
    assert!(rec.iter().all(|r| r.pre_departure_overhead > 0.0));
}

/// Split-merge with exponential service AND the overhead model: the
/// shared draw order upgrades the old deterministic-service-only exact
/// check to fully random workloads, again at k not divisible by l.
#[test]
fn sm_engines_agree_bitwise_with_overhead() {
    for &(l, k, seed) in &[(3usize, 9usize, 77u64), (7, 25, 78), (10, 64, 79)] {
        let mu = k as f64 / l as f64;
        let n = 800;
        let oh = OverheadModel::new(OverheadConfig::paper());
        let mut w1 = mk_workload(0.4, mu, seed);
        let mut model = SplitMerge::new(l, k);
        let rec = run_recursion(&mut model, n, &mut w1, &oh);
        let mut w2 = mk_workload(0.4, mu, seed);
        let mut cal = Calendar::new(Discipline::SplitMerge, l, vec![k as u32]);
        let mut tr = TraceLog::disabled();
        let cal_recs = cal.run(n, &mut w2, &oh, &mut tr);
        assert_bitwise_equal(&rec, &cal_recs, &format!("sm l={l} k={k}"));
    }
}

/// Split-merge with deterministic service: the original exact agreement
/// regression (no draw-order ambiguity at all).
#[test]
fn sm_engines_agree_deterministic_service() {
    let (l, k) = (3usize, 9usize);
    let n = 500;
    let mk = |seed: u64| {
        Workload::new(Exponential::new(0.4).into(), Deterministic::new(0.5).into(), seed)
    };
    let oh = OverheadModel::new(OverheadConfig {
        c_task_ts: 0.01,
        mu_task_ts: f64::INFINITY, // deterministic overhead too
        c_job_pd: 0.05,
        c_task_pd: 1e-4,
    });
    let mut tr = TraceLog::disabled();
    let mut w1 = mk(77);
    let mut model = SplitMerge::new(l, k);
    let rec: Vec<f64> = (0..n)
        .map(|j| {
            let a = w1.next_arrival();
            model.advance(j, a, &mut w1, &oh, &mut tr).departure
        })
        .collect();
    let mut w2 = mk(77);
    let mut cal = Calendar::new(Discipline::SplitMerge, l, vec![k as u32]);
    let cal_recs = cal.run(n, &mut w2, &oh, &mut tr);
    for (j, (d1, r)) in rec.iter().zip(&cal_recs).enumerate() {
        assert!(
            (d1 - r.departure).abs() < 1e-9,
            "job {j}: recursion {d1} vs calendar {}",
            r.departure
        );
    }
}

/// Multi-stage extension sanity at system level: a map+reduce job stream
/// under load keeps FIFO-per-stage work conservation (every stage's task
/// count is served).
#[test]
fn multi_stage_under_load() {
    let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 8, vec![24, 8]);
    let mut w = mk_workload(0.35, 4.0, 9);
    let oh = OverheadModel::none();
    let mut tr = TraceLog::enabled();
    let n = 300;
    let recs = cal.run(n, &mut w, &oh, &mut tr);
    assert_eq!(recs.len(), n);
    assert_eq!(tr.events().len(), n * 32);
    for r in &recs {
        assert!(r.sojourn() > 0.0);
        // 32 tasks at rate 4 → E[workload] = 8; loose sanity bounds.
        assert!(r.workload > 1.0 && r.workload < 40.0);
    }
}
