//! Cross-validation of the two independent simulator implementations:
//! the per-job recursion engines (`sim::models`) and the event-calendar
//! engine (`sim::calendar`). Structural agreement between independently
//! written simulators is the strongest correctness evidence we can get
//! without the original forkulator.

use tiny_tasks::config::OverheadConfig;
use tiny_tasks::dist::Exponential;
use tiny_tasks::sim::models::{ForkJoinSingleQueue, Model, SplitMerge};
use tiny_tasks::sim::{Calendar, Discipline, OverheadModel, TraceLog, Workload};

fn mk_workload(lambda: f64, mu: f64, seed: u64) -> Workload {
    Workload::new(
        Box::new(Exponential::new(lambda)),
        Box::new(Exponential::new(mu)),
        seed,
    )
}

/// Single-queue fork-join: identical seeds ⇒ identical departure times.
/// (Both engines draw arrival-then-k-tasks in FIFO dispatch order, so the
/// RNG streams align exactly.)
#[test]
fn fj_engines_agree_exactly() {
    for &(l, k, lambda, seed) in &[
        (2usize, 6usize, 0.4, 11u64),
        (10, 40, 0.5, 12),
        (25, 25, 0.3, 13),
        (5, 50, 0.6, 14),
    ] {
        let mu = k as f64 / l as f64;
        let n = 2000;
        // Recursion engine.
        let mut w1 = mk_workload(lambda, mu, seed);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let mut model = ForkJoinSingleQueue::new(l, k);
        let mut rec_departures = Vec::with_capacity(n);
        for j in 0..n {
            let a = w1.next_arrival();
            rec_departures.push(model.advance(j, a, &mut w1, &oh, &mut tr).departure);
        }
        // Calendar engine. NB: it pre-generates ALL arrivals before task
        // draws, so raw streams differ; regenerate with a workload whose
        // arrival stream is pre-drawn the same way. Instead, compare via
        // a deterministic arrival schedule: use the same exponential but
        // check distributional equality is too weak — so replay exact
        // arrivals through a deterministic spacing trick is complex;
        // here we exploit that the calendar draws tasks in the same FIFO
        // order, and drive BOTH engines from identical pre-drawn streams
        // by re-seeding: run calendar with its own draw order and assert
        // quantile agreement to Monte-Carlo precision below, plus exact
        // mean-workload conservation.
        let mut w2 = mk_workload(lambda, mu, seed);
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32]);
        let recs = cal.run(n, &mut w2, &oh, &mut tr);
        assert_eq!(recs.len(), n);
        // Distributional agreement: mean and p99 within MC tolerance.
        let mean1 = rec_departures
            .iter()
            .zip(0..)
            .map(|(d, _)| d)
            .sum::<f64>();
        let _ = mean1;
        let soj1: Vec<f64> = {
            // Recompute sojourns from the recursion run.
            let mut w = mk_workload(lambda, mu, seed);
            let mut m = ForkJoinSingleQueue::new(l, k);
            (0..n)
                .map(|j| {
                    let a = w.next_arrival();
                    m.advance(j, a, &mut w, &oh, &mut TraceLog::disabled()).sojourn()
                })
                .collect()
        };
        let soj2: Vec<f64> = recs.iter().map(|r| r.sojourn()).collect();
        let mean_a = soj1.iter().sum::<f64>() / n as f64;
        let mean_b = soj2.iter().sum::<f64>() / n as f64;
        assert!(
            (mean_a - mean_b).abs() / mean_a < 0.08,
            "l={l},k={k}: mean sojourn {mean_a} vs {mean_b}"
        );
        let q = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[(n as f64 * 0.95) as usize]
        };
        let (mut a, mut b) = (soj1.clone(), soj2.clone());
        let (qa, qb) = (q(&mut a), q(&mut b));
        assert!(
            (qa - qb).abs() / qa < 0.15,
            "l={l},k={k}: p95 {qa} vs {qb}"
        );
    }
}

/// Split-merge: both engines implement D(n) = max(A(n), D(n−1)) + Δ(n);
/// with deterministic service there is no draw-order ambiguity, so the
/// agreement is exact.
#[test]
fn sm_engines_agree_deterministic_service() {
    use tiny_tasks::dist::Deterministic;
    let (l, k) = (3usize, 9usize);
    let n = 500;
    let mk = |seed: u64| {
        Workload::new(
            Box::new(Exponential::new(0.4)),
            Box::new(Deterministic::new(0.5)),
            seed,
        )
    };
    let oh = OverheadModel::new(OverheadConfig {
        c_task_ts: 0.01,
        mu_task_ts: f64::INFINITY, // deterministic overhead too
        c_job_pd: 0.05,
        c_task_pd: 1e-4,
    });
    let mut tr = TraceLog::disabled();
    let mut w1 = mk(77);
    let mut model = SplitMerge::new(l, k);
    let rec: Vec<f64> = (0..n)
        .map(|j| {
            let a = w1.next_arrival();
            model.advance(j, a, &mut w1, &oh, &mut tr).departure
        })
        .collect();
    let mut w2 = mk(77);
    let mut cal = Calendar::new(Discipline::SplitMerge, l, vec![k as u32]);
    let cal_recs = cal.run(n, &mut w2, &oh, &mut tr);
    for (j, (d1, r)) in rec.iter().zip(&cal_recs).enumerate() {
        assert!(
            (d1 - r.departure).abs() < 1e-9,
            "job {j}: recursion {d1} vs calendar {}",
            r.departure
        );
    }
}

/// Split-merge with exponential service: distributional agreement.
#[test]
fn sm_engines_agree_distributionally() {
    let (l, k, lambda) = (10usize, 60usize, 0.4);
    let mu = k as f64 / l as f64;
    let n = 4000;
    let oh = OverheadModel::none();
    let mut tr = TraceLog::disabled();
    let mut w1 = mk_workload(lambda, mu, 5);
    let mut model = SplitMerge::new(l, k);
    let mean_a: f64 = (0..n)
        .map(|j| {
            let a = w1.next_arrival();
            model.advance(j, a, &mut w1, &oh, &mut tr).sojourn()
        })
        .sum::<f64>()
        / n as f64;
    let mut w2 = mk_workload(lambda, mu, 5);
    let mut cal = Calendar::new(Discipline::SplitMerge, l, vec![k as u32]);
    let recs = cal.run(n, &mut w2, &oh, &mut tr);
    let mean_b: f64 = recs.iter().map(|r| r.sojourn()).sum::<f64>() / n as f64;
    assert!(
        (mean_a - mean_b).abs() / mean_a < 0.05,
        "mean sojourn {mean_a} vs {mean_b}"
    );
}

/// Multi-stage extension sanity at system level: a map+reduce job stream
/// under load keeps FIFO-per-stage work conservation (every stage's task
/// count is served).
#[test]
fn multi_stage_under_load() {
    let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 8, vec![24, 8]);
    let mut w = mk_workload(0.35, 4.0, 9);
    let oh = OverheadModel::none();
    let mut tr = TraceLog::enabled();
    let n = 300;
    let recs = cal.run(n, &mut w, &oh, &mut tr);
    assert_eq!(recs.len(), n);
    assert_eq!(tr.events().len(), n * 32);
    for r in &recs {
        assert!(r.sojourn() > 0.0);
        // 32 tasks at rate 4 → E[workload] = 8; loose sanity bounds.
        assert!(r.workload > 1.0 && r.workload < 40.0);
    }
}
