//! Cross-validation: the AOT JAX/Pallas artifacts (via PJRT) must agree
//! with the pure-Rust `analysis` reference. This is the load-bearing test
//! of the three-layer architecture: it exercises
//! `make artifacts` → `HloModuleProto::from_text_file` → compile → execute
//! and checks numeric parity.
//!
//! Requires `artifacts/` (run `make artifacts`); tests are skipped with a
//! note if the artifacts are missing so `cargo test` stays usable before
//! the Python step.

use tiny_tasks::analysis::{self, BoundModel, BoundParams};
use tiny_tasks::config::OverheadConfig;
use tiny_tasks::runtime::{BoundQuery, BoundsEngine, EngineKind, ErlangQuery};

fn artifact_engine() -> Option<BoundsEngine> {
    // Keep CWD-independent: tests run from the workspace root.
    match BoundsEngine::artifact() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP artifact cross-validation: {err}");
            None
        }
    }
}

/// Grid-vs-golden-section optimizers differ slightly; τ is flat near the
/// optimum so 1% relative tolerance is appropriate (DESIGN.md §3).
const REL_TOL: f64 = 0.01;

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-12)
}

#[test]
fn bounds_artifact_matches_native() {
    let Some(eng) = artifact_engine() else { return };
    assert_eq!(eng.kind(), EngineKind::Artifact);

    // A spread of figure-relevant configurations: Fig. 8 (l=50, λ=0.5,
    // μ=k/l), Fig. 13 (ε=1e-6), M/M/1, small clusters, with and without
    // overhead.
    let mut queries = Vec::new();
    for &(k, l, lambda, eps) in &[
        (400usize, 50usize, 0.5, 0.01),
        (1000, 50, 0.5, 0.01),
        (600, 50, 0.5, 1e-6),
        (100, 10, 0.3, 0.001),
        (1, 1, 0.5, 0.01),
        (64, 16, 0.4, 0.01),
    ] {
        let mu = k as f64 / l as f64;
        queries.push(BoundQuery { k, l, lambda, mu, epsilon: eps, overhead: None });
        queries.push(BoundQuery {
            k,
            l,
            lambda,
            mu,
            epsilon: eps,
            overhead: Some(OverheadConfig::paper()),
        });
    }

    let rows = eng.bounds(&queries).unwrap();
    for (q, row) in queries.iter().zip(&rows) {
        let p = BoundParams {
            l: q.l,
            k: q.k,
            lambda: q.lambda,
            mu: q.mu,
            epsilon: q.epsilon,
            overhead: q.overhead,
        };
        let clean = BoundParams { overhead: None, ..p };
        let native_sm = analysis::sojourn_bound(BoundModel::SplitMergeTiny, &p);
        let native_fj = analysis::sojourn_bound(BoundModel::ForkJoinTiny, &p);
        let native_id = analysis::sojourn_bound(BoundModel::Ideal, &clean);
        check_pair("sm", q, row.split_merge, native_sm);
        check_pair("fj", q, row.fork_join, native_fj);
        check_pair("ideal", q, row.ideal, native_id);
    }
}

fn check_pair(tag: &str, q: &BoundQuery, artifact: Option<f64>, native: Option<f64>) {
    match (artifact, native) {
        (Some(a), Some(n)) => {
            assert!(
                close(a, n, REL_TOL),
                "{tag} {q:?}: artifact {a} vs native {n}"
            );
        }
        (None, None) => {}
        (a, n) => panic!("{tag} {q:?}: feasibility disagrees: artifact {a:?} native {n:?}"),
    }
}

#[test]
fn erlang_artifact_matches_native() {
    let Some(eng) = artifact_engine() else { return };
    let queries: Vec<ErlangQuery> = [(5usize, 20u32), (10, 20), (20, 20), (1, 1), (10, 1)]
        .iter()
        .map(|&(l, kappa)| ErlangQuery {
            l,
            kappa,
            lambda: 0.5,
            mu: kappa as f64, // utilization λκ/μ = 0.5
            epsilon: 1e-3,
        })
        .collect();
    let rows = eng.erlang(&queries).unwrap();
    for (q, row) in queries.iter().zip(&rows) {
        let native_mean = analysis::erlang::mean_max_erlang(q.l, q.kappa, q.mu);
        let native_rho = analysis::erlang::max_utilization_big_tasks(q.l, q.kappa, q.mu);
        assert!(
            close(row.mean_service, native_mean, 1e-3),
            "{q:?}: E[Δ] {} vs {native_mean}",
            row.mean_service
        );
        assert!(
            close(row.max_utilization, native_rho, 1e-3),
            "{q:?}: ρ* {} vs {native_rho}",
            row.max_utilization
        );
        let native_tau = analysis::sojourn_bound(
            BoundModel::SplitMergeBigErlang { kappa: q.kappa },
            &BoundParams {
                l: q.l,
                k: q.l,
                lambda: q.lambda,
                mu: q.mu,
                epsilon: q.epsilon,
                overhead: None,
            },
        );
        match (row.sojourn, native_tau) {
            (Some(a), Some(n)) => assert!(
                close(a, n, REL_TOL),
                "{q:?}: τ {a} vs {n}"
            ),
            (None, None) => {}
            (a, n) => panic!("{q:?}: feasibility disagrees: {a:?} vs {n:?}"),
        }
    }
}

#[test]
fn stability_artifact_matches_eq20() {
    let Some(eng) = artifact_engine() else { return };
    let pairs: Vec<(usize, usize)> =
        vec![(50, 50), (200, 50), (1000, 50), (3000, 50), (10, 10), (1, 1)];
    let got = eng.stability(&pairs).unwrap();
    for (&(k, l), &rho) in pairs.iter().zip(&got) {
        let expect = analysis::stability::sm_tiny_tasks(l, k);
        assert!(
            close(rho, expect, 1e-9),
            "(k={k}, l={l}): {rho} vs {expect}"
        );
    }
}

/// Exactness anchor: the artifact M/M/1 bound must dominate and stay
/// within 30% of the exact M/M/1 0.99-quantile ln(100)/(μ−λ).
#[test]
fn artifact_mm1_anchor() {
    let Some(eng) = artifact_engine() else { return };
    let rows = eng
        .bounds(&[BoundQuery {
            k: 1,
            l: 1,
            lambda: 0.5,
            mu: 1.0,
            epsilon: 0.01,
            overhead: None,
        }])
        .unwrap();
    let exact = (100.0f64).ln() / 0.5;
    let got = rows[0].fork_join.unwrap();
    assert!(got >= exact, "bound below exact: {got} < {exact}");
    assert!(got < exact * 1.3, "bound too loose: {got} vs {exact}");
}
