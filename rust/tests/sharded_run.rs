//! Sharded single-run execution regressions.
//!
//! Sharding is a replication scheme: the shard count changes the sample
//! stream (per-shard seeds from `spawn_seeds`), so determinism is per
//! (seed, shard count). What must NEVER change results is the *thread*
//! count — shards merge in shard-index order regardless of completion
//! order — and a single shard must be the unsharded engine bit for bit.

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::dist::{Dist, Erlang, Exponential};
use tiny_tasks::sim::{self, RunOptions, Workload};

fn base(jobs: usize) -> SimulationConfig {
    SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: 4,
        tasks_per_job: 8,
        arrival: ArrivalConfig { interarrival: "exp:0.3".into() },
        service: ServiceConfig { execution: "exp:2.0".into() },
        jobs,
        warmup: jobs / 10,
        seed: 77,
        overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// `threads = 1` (and a single shard on any pool size) is bit-for-bit
/// today's unsharded engine.
#[test]
fn single_shard_is_bitwise_unsharded() {
    let cfg = base(4_000);
    let mut plain = sim::run(&cfg, RunOptions::default()).unwrap();
    for opts in [
        RunOptions { threads: 1, ..Default::default() },
        RunOptions { shards: 1, threads: 8, ..Default::default() },
    ] {
        let mut sharded = sim::run(&cfg, opts).unwrap();
        assert_eq!(plain.sojourn_summary.mean(), sharded.sojourn_summary.mean());
        assert_eq!(
            plain.sojourn_summary.variance(),
            sharded.sojourn_summary.variance()
        );
        assert_eq!(plain.overhead_summary.mean(), sharded.overhead_summary.mean());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(plain.sojourn_quantile(q), sharded.sojourn_quantile(q));
            assert_eq!(plain.waiting_quantile(q), sharded.waiting_quantile(q));
        }
    }
}

/// At a fixed shard count the thread count is unobservable: merged
/// summaries and quantiles are bitwise identical for 1 vs 4 workers
/// (the Welford/sketch merge order is shard-index order, not completion
/// order).
#[test]
fn thread_count_never_changes_results() {
    let cfg = base(6_000);
    let mut serial =
        sim::run(&cfg, RunOptions { shards: 4, threads: 1, ..Default::default() }).unwrap();
    let mut parallel =
        sim::run(&cfg, RunOptions { shards: 4, threads: 4, ..Default::default() }).unwrap();
    assert_eq!(serial.sojourn_summary.mean(), parallel.sojourn_summary.mean());
    assert_eq!(
        serial.sojourn_summary.variance(),
        parallel.sojourn_summary.variance()
    );
    assert_eq!(serial.sojourn_summary.min(), parallel.sojourn_summary.min());
    assert_eq!(serial.sojourn_summary.max(), parallel.sojourn_summary.max());
    assert_eq!(serial.overhead_summary.mean(), parallel.overhead_summary.mean());
    assert_eq!(
        serial.redundant_summary.count(),
        parallel.redundant_summary.count()
    );
    for (a, b) in serial.thirds.iter().zip(&parallel.thirds) {
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
    }
    for q in [0.5, 0.9, 0.99] {
        assert_eq!(serial.sojourn_quantile(q), parallel.sojourn_quantile(q));
    }
}

/// Different shard counts draw different sample streams, but they sample
/// the same law: merged means agree with the unsharded run within
/// statistical tolerance, and every measured job is accounted for.
#[test]
fn shard_count_changes_stream_not_the_law() {
    let cfg = base(20_000);
    let plain = sim::run(&cfg, RunOptions::default()).unwrap();
    let m0 = plain.sojourn_summary.mean();
    for shards in [2usize, 4, 7] {
        let opts = RunOptions { shards, threads: 2, ..Default::default() };
        let res = sim::run(&cfg, opts).unwrap();
        assert_eq!(res.sojourn.len(), cfg.jobs, "shards={shards}");
        assert_eq!(res.sojourn_summary.count(), cfg.jobs as u64);
        let m = res.sojourn_summary.mean();
        assert!(
            (m - m0).abs() / m0 < 0.10,
            "shards={shards}: merged mean {m} vs unsharded {m0}"
        );
        // Same (seed, shard count) → same result.
        let res2 = sim::run(&cfg, opts).unwrap();
        assert_eq!(m, res2.sojourn_summary.mean());
    }
}

/// Streaming shards merge their P² banks: identical sample streams to
/// the exact sharded run (bitwise-equal summaries), quantiles within P²
/// tolerance of the exact merged sketch.
#[test]
fn streaming_shards_match_exact_shards() {
    let cfg = base(24_000);
    let opts_exact = RunOptions { shards: 4, threads: 2, ..Default::default() };
    let opts_stream = RunOptions {
        shards: 4,
        threads: 2,
        streaming: true,
        streaming_q: Some(0.75),
        ..Default::default()
    };
    let mut exact = sim::run(&cfg, opts_exact).unwrap();
    let mut stream = sim::run(&cfg, opts_stream).unwrap();
    assert_eq!(exact.sojourn_summary.mean(), stream.sojourn_summary.mean());
    assert_eq!(exact.sojourn.len(), stream.sojourn.len());
    for q in [0.5, 0.9, 0.99, 0.75] {
        let (a, b) = (exact.sojourn_quantile(q), stream.sojourn_quantile(q));
        assert!(
            (a - b).abs() / a < 0.15,
            "q={q}: exact sharded {a} vs P²-merged {b}"
        );
    }
}

/// Per-job records and traces are single-stream outputs: sharded runs
/// refuse them loudly instead of returning one shard's slice.
#[test]
fn sharded_run_rejects_record_and_trace() {
    let cfg = base(1_000);
    for opts in [
        RunOptions { shards: 2, record_jobs: true, ..Default::default() },
        RunOptions { threads: 2, trace: true, ..Default::default() },
    ] {
        assert!(sim::run(&cfg, opts).is_err());
    }
}

/// `Dist::draw_batch` through the `Workload` layer: the batch path is
/// bit-for-bit the one-at-a-time path, and `TT_NO_FAST_EXP=1` (dyn
/// dispatch) produces the identical stream.
///
/// Both comparisons live in ONE test so the env-var set/remove cannot
/// interleave with itself across test threads (the var is read at
/// `Workload` construction; see scenario_equivalence.rs for the same
/// pattern).
#[test]
fn draw_batch_bitwise_with_and_without_fast_path() {
    assert!(std::env::var_os("TT_NO_FAST_EXP").is_none(), "leaked env var");
    let dists: Vec<(Dist, Dist)> = vec![
        (Exponential::new(1.6).into(), Exponential::new(1.6).into()),
        (Erlang::new(4, 2.0).into(), Erlang::new(4, 2.0).into()),
    ];
    let mut fast_batches: Vec<Vec<f64>> = Vec::new();
    for (da, db) in dists {
        let mut one = Workload::new(Exponential::new(0.5).into(), da, 123);
        let mut batch = Workload::new(Exponential::new(0.5).into(), db, 123);
        let singles: Vec<f64> = (0..513).map(|_| one.next_execution()).collect();
        let mut buf = vec![0.0; 513];
        batch.next_executions(&mut buf);
        assert_eq!(singles, buf, "batch path diverges from single draws");
        // Interleaving arrivals keeps the shared stream aligned.
        assert_eq!(one.next_arrival(), batch.next_arrival());
        fast_batches.push(buf);
    }
    // Same draws with the fast path disabled: dyn dispatch, same stream.
    std::env::set_var("TT_NO_FAST_EXP", "1");
    let dyn_dists: Vec<Dist> =
        vec![Exponential::new(1.6).into(), Erlang::new(4, 2.0).into()];
    for (d, fast) in dyn_dists.into_iter().zip(&fast_batches) {
        let mut w = Workload::new(Exponential::new(0.5).into(), d, 123);
        let mut buf = vec![0.0; 513];
        w.next_executions(&mut buf);
        assert_eq!(&buf, fast, "TT_NO_FAST_EXP batch diverges from fast path");
    }
    std::env::remove_var("TT_NO_FAST_EXP");
}
