//! Artifact-engine benchmark (§Perf L1/L2 target): latency of one padded
//! batch (128 configs) through the AOT bounds/erlang artifacts via PJRT,
//! against the pure-Rust native engine on the same queries.
//!
//! `cargo bench --bench bench_runtime`

use tiny_tasks::runtime::{BoundQuery, BoundsEngine, ErlangQuery};
use tiny_tasks::util::bench::Bencher;

fn queries(n: usize) -> Vec<BoundQuery> {
    (0..n)
        .map(|i| {
            let k = 50 + 50 * (i % 50);
            BoundQuery {
                k,
                l: 50,
                lambda: 0.5,
                mu: k as f64 / 50.0,
                epsilon: 0.01,
                overhead: None,
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    let native = BoundsEngine::native();
    let qs = queries(128);

    let rn = b.bench("native_bounds_batch128", || native.bounds(&qs).unwrap().len()).mean;
    println!("    -> {:.1} configs/s", 128.0 / rn.as_secs_f64());

    match BoundsEngine::artifact() {
        Ok(artifact) => {
            let ra = b
                .bench("artifact_bounds_batch128", || {
                    artifact.bounds(&qs).unwrap().len()
                })
                .mean;
            println!("    -> {:.1} configs/s", 128.0 / ra.as_secs_f64());
            println!(
                "    artifact/native latency ratio: {:.2}x",
                ra.as_secs_f64() / rn.as_secs_f64()
            );
            let eq: Vec<ErlangQuery> = (0..128)
                .map(|i| ErlangQuery {
                    l: 1 + i % 50,
                    kappa: 20,
                    lambda: 0.5,
                    mu: 20.0,
                    epsilon: 1e-6,
                })
                .collect();
            let re = b
                .bench("artifact_erlang_batch128", || {
                    artifact.erlang(&eq).unwrap().len()
                })
                .mean;
            println!("    -> {:.1} configs/s", 128.0 / re.as_secs_f64());
            let pairs: Vec<(usize, usize)> = (0..128).map(|i| (50 + i * 10, 50)).collect();
            b.bench("artifact_stability_batch128", || {
                artifact.stability(&pairs).unwrap().len()
            });
        }
        Err(e) => println!("artifacts unavailable ({e}); native only"),
    }
    b.finish();
    Ok(())
}
