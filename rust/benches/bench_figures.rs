//! Figure-regeneration bench: runs every paper figure's pipeline at a
//! miniature scale and reports wall time per figure. This is the
//! "regenerate every table and figure" target (DESIGN.md §4); full-size
//! CSVs come from `tiny-tasks figure all --scale quick|paper`.
//!
//! `cargo bench --bench bench_figures`

use std::time::Instant;
use tiny_tasks::coordinator::figures::{self, FigureCtx, Scale};
use tiny_tasks::runtime::BoundsEngine;
use tiny_tasks::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("reports/bench");
    std::fs::create_dir_all(&out)?;
    let engine = BoundsEngine::auto();
    let pool = ThreadPool::with_default_size();
    let ctx = FigureCtx { out_dir: &out, scale: Scale::Quick, seed: 1, engine: &engine, pool: &pool };

    println!("== figure pipelines (quick scale) ==");
    let mut total = 0.0;
    for id in figures::ALL {
        let t0 = Instant::now();
        figures::run(id, &ctx)?;
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("--- {id}: {dt:.2}s\n");
    }
    println!("all figures regenerated in {total:.1}s -> {}", out.display());
    Ok(())
}
