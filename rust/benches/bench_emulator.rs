//! sparklite benchmark (§Perf L3): per-task dispatch overhead of the
//! emulator stack (serialize → schedule → transmit → deserialize →
//! execute(0) → result round-trip) and end-to-end throughput with real
//! payloads — the intrinsic overhead floor that the calibration pipeline
//! measures.
//!
//! `cargo bench --bench bench_emulator`

use tiny_tasks::config::{EmulatorConfig, ModelKind};
use tiny_tasks::emulator::{self, Cluster, Payload};
use tiny_tasks::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new(
        std::time::Duration::from_millis(300),
        std::time::Duration::from_millis(1500),
    );

    // Dispatch overhead: near-zero-duration tasks, measure tasks/sec.
    {
        let cfg = EmulatorConfig {
            executors: 4,
            tasks_per_job: 64,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "det:0.0001".into(),
            execution: "det:0.000001".into(),
            time_scale: 1.0,
            jobs: 20,
            warmup: 0,
            seed: 1,
            inject_overhead: None,
            workers: None,
        };
        let r = b.bench("dispatch_1280_null_tasks", || {
            emulator::run(&cfg).unwrap().listener.tasks.len()
        });
        let tasks = 20.0 * 64.0;
        println!(
            "    -> {:.0} tasks/s dispatch ({:.1} µs/task overhead floor)",
            tasks / r.mean.as_secs_f64(),
            r.mean.as_secs_f64() / tasks * 1e6
        );
    }

    // Mean intrinsic per-task overhead measured by the listener.
    {
        let cfg = EmulatorConfig {
            executors: 4,
            tasks_per_job: 32,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "exp:2.0".into(),
            execution: "exp:4.0".into(),
            time_scale: 0.01,
            jobs: 60,
            warmup: 6,
            seed: 2,
            inject_overhead: None,
            workers: None,
        };
        let res = emulator::run(&cfg).unwrap();
        let mean_oh: f64 = res.listener.tasks.iter().map(|t| t.overhead()).sum::<f64>()
            / res.listener.tasks.len() as f64;
        println!(
            "intrinsic task overhead: mean {:.1} µs wall ({:.3} ms emulated), fraction {:.4}",
            mean_oh * 1e6,
            mean_oh / cfg.time_scale * 1e3,
            res.listener.mean_overhead_fraction()
        );
    }

    // Real-payload throughput (matmul + wordcount mix).
    {
        let cfg = EmulatorConfig {
            executors: 4,
            tasks_per_job: 16,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "det:0.001".into(),
            execution: "det:1".into(),
            time_scale: 1.0,
            jobs: 8,
            warmup: 0,
            seed: 3,
            inject_overhead: None,
            workers: None,
        };
        let r = b.bench("real_payload_128_tasks", || {
            Cluster::run_with(&cfg, |job, task| {
                if task % 2 == 0 {
                    Payload::MatMul { n: 48, seed: job ^ task as u64 }
                } else {
                    Payload::WordCount { text: "a b c d e f g h ".repeat(64), top: 5 }
                }
            })
            .unwrap()
            .listener
            .tasks
            .len()
        });
        println!("    -> {:.0} real tasks/s", 128.0 / r.mean.as_secs_f64());
    }
    b.finish();
}
