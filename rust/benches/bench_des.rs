//! DES hot-path benchmark (§Perf L3 target: tasks/second through the
//! simulator's heap recursion). One bench per model, plus the tiny-tasks
//! sweep shapes from Fig. 8 to keep the perf numbers tied to the paper's
//! workload.
//!
//! `cargo bench --bench bench_des`

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::dist::Exponential;
use tiny_tasks::sim::{self, Calendar, Discipline, OverheadModel, RunOptions, TraceLog, Workload};
use tiny_tasks::util::bench::Bencher;

fn cfg(model: ModelKind, l: usize, k: usize, jobs: usize) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.5".into() },
        service: ServiceConfig { execution: format!("exp:{}", k as f64 / l as f64) },
        jobs,
        warmup: 0,
        seed: 1,
        overhead: None,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

fn main() {
    let mut b = Bencher::default();
    // Each iteration simulates a fixed batch of jobs; report tasks/sec.
    for (name, model, l, k, jobs) in [
        ("sm_l50_k400", ModelKind::SplitMerge, 50usize, 400usize, 200usize),
        ("sqfj_l50_k400", ModelKind::ForkJoinSingleQueue, 50, 400, 200),
        ("sqfj_l50_k2500", ModelKind::ForkJoinSingleQueue, 50, 2500, 40),
        ("fjps_l50", ModelKind::ForkJoinPerServer, 50, 50, 2000),
        ("ideal_l50_k400", ModelKind::Ideal, 50, 400, 500),
    ] {
        let c = cfg(model, l, k, jobs);
        let r = b.bench(name, || {
            sim::run(&c, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        let tasks_per_iter = (jobs * k) as f64;
        println!(
            "    -> {:.1} M tasks/s",
            tasks_per_iter / r.mean.as_secs_f64() / 1e6
        );
    }
    // Overhead-model sampling cost on the hot path.
    {
        let c = SimulationConfig {
            overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
            ..cfg(ModelKind::ForkJoinSingleQueue, 50, 400, 200)
        };
        let r = b.bench("sqfj_l50_k400_overhead", || {
            sim::run(&c, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        println!(
            "    -> {:.1} M tasks/s",
            (200 * 400) as f64 / r.mean.as_secs_f64() / 1e6
        );
    }
    // Streaming-stats mode: quantiles via P², no sample storage.
    {
        let c = cfg(ModelKind::ForkJoinSingleQueue, 50, 400, 200);
        let r = b.bench("sqfj_l50_k400_streaming", || {
            sim::run(&c, RunOptions { streaming: true, ..Default::default() })
                .unwrap()
                .sojourn_summary
                .count()
        });
        println!(
            "    -> {:.1} M tasks/s",
            (200 * 400) as f64 / r.mean.as_secs_f64() / 1e6
        );
    }
    // Event-calendar engine, both disciplines (the O(events·log l) path).
    for (name, disc, l, k, jobs) in [
        ("cal_sm_l50_k400", Discipline::SplitMerge, 50usize, 400u32, 200usize),
        ("cal_sqfj_l50_k400", Discipline::SingleQueueForkJoin, 50, 400, 200),
    ] {
        let mut cal = Calendar::new(disc, l, vec![k]);
        let oh = OverheadModel::none();
        let mu = k as f64 / l as f64;
        let r = b.bench(name, || {
            let mut w = Workload::new(Exponential::new(0.5).into(), Exponential::new(mu).into(), 1);
            let mut tr = TraceLog::disabled();
            cal.run(jobs, &mut w, &oh, &mut tr).len()
        });
        println!(
            "    -> {:.1} M tasks/s",
            (jobs * k as usize) as f64 / r.mean.as_secs_f64() / 1e6
        );
    }
    b.finish();
}
