//! Table §2.6 bench: regenerate the overhead-parameter table by running
//! the calibration pipeline against sparklite with the paper's overhead
//! injected, and print fitted-vs-injected (the reproduction of the
//! paper's four-parameter table).
//!
//! `cargo bench --bench bench_calibration`

use std::time::Instant;
use tiny_tasks::config::{EmulatorConfig, ModelKind, OverheadConfig};
use tiny_tasks::coordinator::calibrate;

fn main() {
    let injected = OverheadConfig::paper();
    // NB: `calibrate` reuses one execution spec across all k, so pick a
    // task size small enough that the *largest* k stays stable
    // (ρ = λ k E[exec] / l: 0.2 at k=64, 0.6 at k=192) and a time scale
    // that respects the 1-core ~2000 tasks/s wall rate cap.
    let base = EmulatorConfig {
        executors: 8,
        tasks_per_job: 64,
        mode: ModelKind::ForkJoinSingleQueue,
        interarrival: "exp:0.4".into(),
        execution: "exp:16.0".into(),
        time_scale: 0.06,
        jobs: 150,
        warmup: 15,
        seed: 5,
        inject_overhead: Some(injected),
        workers: None,
    };
    let t0 = Instant::now();
    let cal = calibrate::calibrate(&base, &[64, 192]).expect("calibration");
    let dt = t0.elapsed().as_secs_f64();

    println!("== Table (Sec. 2.6): overhead model parameters ==");
    println!("{:<14} {:>14} {:>14}", "parameter", "injected", "fitted");
    println!(
        "{:<14} {:>11.3} ms {:>11.3} ms",
        "c_task_ts",
        injected.c_task_ts * 1e3,
        cal.fitted.c_task_ts * 1e3
    );
    println!(
        "{:<14} {:>10.0} 1/s {:>10.0} 1/s",
        "mu_task_ts", injected.mu_task_ts, cal.fitted.mu_task_ts
    );
    println!(
        "{:<14} {:>11.3} ms {:>11.3} ms",
        "c_job_pd",
        injected.c_job_pd * 1e3,
        cal.fitted.c_job_pd * 1e3
    );
    println!(
        "{:<14} {:>11.5} ms {:>11.5} ms",
        "c_task_pd",
        injected.c_task_pd * 1e3,
        cal.fitted.c_task_pd * 1e3
    );
    println!(
        "\nPP distance: no-overhead {:.4} -> fitted {:.4}  ({} tasks, {} jobs, {dt:.1}s)",
        cal.pp_without_overhead, cal.pp_with_overhead, cal.tasks_measured, cal.jobs_measured
    );
    println!("note: fitted values include sparklite's intrinsic overhead on top of the injection.");
}
