//! Ablation studies on the modelling choices DESIGN.md calls out:
//!
//! 1. **In-order departures** — Th. 2 analyzes a variant of single-queue
//!    fork-join where jobs depart in sequence; how much sojourn does that
//!    constraint add over the free (Spark-like) system?
//! 2. **Overhead placement** — task-service (blocking) vs pre-departure
//!    (non-blocking) overhead have different system effects (Sec. 6);
//!    isolate each component's contribution at fixed total overhead.
//! 3. **Non-exponential tasks** — Lemma 1/Th. 2 need memorylessness; how
//!    does the tiny-tasks *benefit* (simulated) change under lighter
//!    (Weibull k=2), heavier (Pareto α=2.5) and deterministic tails?
//!
//! `cargo bench --bench bench_ablation`

use tiny_tasks::config::{ArrivalConfig, ModelKind, OverheadConfig, ServiceConfig, SimulationConfig};
use tiny_tasks::sim::{self, RunOptions};

fn base(l: usize, k: usize, exec: String, jobs: usize) -> SimulationConfig {
    SimulationConfig {
        model: ModelKind::ForkJoinSingleQueue,
        servers: l,
        tasks_per_job: k,
        arrival: ArrivalConfig { interarrival: "exp:0.5".into() },
        service: ServiceConfig { execution: exec },
        jobs,
        warmup: jobs / 10,
        seed: 99,
        overhead: None,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

fn p99(cfg: &SimulationConfig, opts: RunOptions) -> f64 {
    sim::run(cfg, opts).unwrap().sojourn_quantile(0.99)
}

fn main() {
    let (l, jobs) = (50usize, 40_000usize);

    println!("== ablation 1: Th.2 in-order departure constraint (l=50) ==");
    println!("{:>6} {:>12} {:>12} {:>8}", "k", "free p99", "inorder p99", "gap");
    for k in [50usize, 200, 800] {
        let cfg = base(l, k, format!("exp:{}", k as f64 / l as f64), jobs);
        let free = p99(&cfg, RunOptions::default());
        let ordered = p99(&cfg, RunOptions { in_order_departures: true, ..Default::default() });
        println!(
            "{k:>6} {free:>12.3} {ordered:>12.3} {:>7.2}%",
            (ordered / free - 1.0) * 100.0
        );
    }

    println!("\n== ablation 2: overhead placement at fixed total (k=600) ==");
    let k = 600usize;
    let mu = k as f64 / l as f64;
    // Total overhead budget per task ≈ 3.1 ms; as pre-departure it is
    // k·c_task_pd with the same per-task magnitude.
    let variants: [(&str, OverheadConfig); 4] = [
        ("none", OverheadConfig::zero()),
        (
            "task-service only",
            OverheadConfig { c_task_ts: 3.1e-3, mu_task_ts: f64::INFINITY, c_job_pd: 0.0, c_task_pd: 0.0 },
        ),
        (
            "pre-departure only",
            OverheadConfig { c_task_ts: 0.0, mu_task_ts: f64::INFINITY, c_job_pd: 0.0, c_task_pd: 3.1e-3 },
        ),
        ("paper split", OverheadConfig::paper()),
    ];
    println!("{:<20} {:>12} {:>12}", "variant", "SM p99", "FJ p99");
    for (name, oh) in variants {
        let mut sm_cfg = base(l, k, format!("exp:{mu}"), jobs);
        sm_cfg.model = ModelKind::SplitMerge;
        sm_cfg.overhead = Some(oh);
        let mut fj_cfg = base(l, k, format!("exp:{mu}"), jobs);
        fj_cfg.overhead = Some(oh);
        println!(
            "{name:<20} {:>12.3} {:>12.3}",
            p99(&sm_cfg, RunOptions::default()),
            p99(&fj_cfg, RunOptions::default())
        );
    }
    println!("(blocking task overhead hurts both; pre-departure only shifts FJ departures\n but *blocks* the SM pipeline — the Sec. 6.2 asymmetry)");

    println!("\n== ablation 3: task-time distribution vs tinyfication benefit ==");
    println!("{:>22} {:>10} {:>10} {:>10}", "distribution", "k=50", "k=600", "gain");
    for (name, spec50, spec600) in [
        ("exponential", "exp:1".to_string(), format!("exp:{}", 600.0 / 50.0)),
        // Same mean task times: Weibull k=2 (light tail), Pareto α=2.5
        // (heavy tail, mean = α·xm/(α−1)), deterministic.
        ("weibull(2) light", "weibull:2:1.1284".into(), "weibull:2:0.09403".into()),
        ("pareto(2.5) heavy", "pareto:2.5:0.6".into(), "pareto:2.5:0.05".into()),
        ("deterministic", "det:1".into(), format!("det:{}", 50.0 / 600.0)),
    ] {
        let q50 = p99(&base(l, 50, spec50, jobs), RunOptions::default());
        let q600 = p99(&base(l, 600, spec600, jobs), RunOptions::default());
        println!(
            "{name:>22} {q50:>10.3} {q600:>10.3} {:>9.1}%",
            (1.0 - q600 / q50) * 100.0
        );
    }
    println!("(the heavier the tail, the bigger the tiny-tasks win — variance reduction\n is the mechanism; deterministic tasks gain only queue-packing effects)");
}
