//! Stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! This build environment does not ship the native XLA library, so every
//! entry point reports the backend as unavailable. The tiny-tasks bounds
//! engine detects this at `PjRtClient::cpu()` / artifact-load time and
//! falls back to the pure-Rust `analysis` implementation (see
//! `rust/src/runtime/engine.rs::BoundsEngine::auto`). Replacing this stub
//! with the real bindings re-enables the AOT artifact hot path without
//! any change to the tiny-tasks sources.

use std::fmt;

/// Error raised by every stubbed entry point.
#[derive(Debug)]
pub struct Error {
    what: &'static str,
}

impl Error {
    fn unavailable(what: &'static str) -> Self {
        Self { what }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla backend unavailable in this build ({}): native xla_extension not linked",
            self.what
        )
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client — always unavailable in the stub.
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation — unreachable (no client can exist).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute — unreachable (no executable can exist).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy back to host — unreachable.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// HLO module proto handle (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO-text file — always unavailable in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed proto (pure constructor; kept infallible like the
    /// real bindings).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// Host literal (stub: carries the f64 payload so pure host-side
/// construction keeps working).
pub struct Literal {
    data: Vec<f64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1(data: &[f64]) -> Self {
        Self { data: data.to_vec() }
    }

    /// Reshape — shape-compatible reshapes succeed host-side.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::unavailable("Literal::reshape"));
        }
        Ok(Literal { data: self.data.clone() })
    }

    /// Unwrap a 1-tuple — unreachable (device results never exist).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a typed vector — unreachable for device results.
    pub fn to_vec<T: FromF64>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }
}

/// Conversion helper for [`Literal::to_vec`].
pub trait FromF64 {
    /// Convert from the stored f64 payload.
    fn from_f64(x: f64) -> Self;
}

impl FromF64 for f64 {
    fn from_f64(x: f64) -> Self {
        x
    }
}

impl FromF64 for f32 {
    fn from_f64(x: f64) -> Self {
        x as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_host_side_ops() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
