//! Minimal offline implementation of the `log` crate's facade API — only
//! the surface tiny-tasks uses: the five level macros, `Level`,
//! `LevelFilter`, `Metadata`, `Record`, the `Log` trait, and the global
//! `set_logger` / `set_max_level` / `max_level` functions.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity level of a single record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// Unrecoverable errors.
    Error = 1,
    /// Recoverable problems.
    Warn,
    /// High-level progress.
    Info,
    /// Diagnostic detail.
    Debug,
    /// Very verbose tracing.
    Trace,
}

/// Global maximum-level filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// Disable all logging.
    Off = 0,
    /// `Level::Error` only.
    Error,
    /// Up to `Level::Warn`.
    Warn,
    /// Up to `Level::Info`.
    Info,
    /// Up to `Level::Debug`.
    Debug,
    /// Everything.
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level + target module path).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// Record level.
    pub fn level(&self) -> Level {
        self.level
    }
    /// Record target (module path by default).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record handed to the installed [`Log`] backend.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// Record level.
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    /// Record target.
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    /// Record metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    /// The formatted message.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    /// Whether a record with this metadata would be logged.
    fn enabled(&self, metadata: &Metadata) -> bool;
    /// Log the record.
    fn log(&self, record: &Record);
    /// Flush any buffered output.
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger; errors if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum log level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum log level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro implementation detail: dispatch one record to the backend.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

/// Log at an explicit level.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

/// Log at `Level::Error`.
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at `Level::Warn`.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at `Level::Info`.
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at `Level::Debug`.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at `Level::Trace`.
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Warn <= LevelFilter::Warn);
        assert!(Level::Info > LevelFilter::Warn);
        assert!(!(Level::Trace <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
