//! Minimal offline implementation of the `anyhow` error-handling API —
//! the surface tiny-tasks uses: [`Error`], [`Result`], [`Context`], and
//! the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! `Error` is an erased boxed error plus a stack of context messages.
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! error type) possible.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: the original cause plus outer context messages,
/// most recent first.
pub struct Error {
    context: Vec<String>,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

/// Plain-message error used by [`Error::msg`] and the macros.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { context: Vec::new(), source: Box::new(MessageError(message.to_string())) }
    }

    /// Create an error from any standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { context: Vec::new(), source: Box::new(error) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.insert(0, context.to_string());
        self
    }

    /// The root cause (the innermost wrapped error).
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = self.source.as_ref();
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }

    /// Iterate over the full message chain, outermost first.
    fn chain_messages(&self) -> Vec<String> {
        let mut msgs: Vec<String> = self.context.clone();
        msgs.push(self.source.to_string());
        let mut cause: &(dyn StdError + 'static) = self.source.as_ref();
        while let Some(next) = cause.source() {
            msgs.push(next.to_string());
            cause = next;
        }
        msgs
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_messages();
        if f.alternate() {
            // `{:#}` prints the whole chain, outermost first.
            f.write_str(&msgs.join(": "))
        } else {
            f.write_str(&msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_messages();
        write!(f, "{}", msgs[0])?;
        if msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Self::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("missing file"));
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let res: std::result::Result<(), std::io::Error> = Err(io_err());
        let err = res.with_context(|| "reading config").unwrap_err();
        let err = Err::<(), Error>(err).context("loading experiment").unwrap_err();
        assert_eq!(err.to_string(), "loading experiment");
        let full = format!("{err:#}");
        assert_eq!(full, "loading experiment: reading config: missing file");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("empty csv").unwrap_err();
        assert_eq!(err.to_string(), "empty csv");
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad k = {}", 7);
        assert_eq!(e.to_string(), "bad k = 7");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(3).is_err());
        assert!(f(11).is_err());
    }
}
