"""L2 + AOT: the lowered graphs produce valid HLO text with the expected
entry layouts, and the stability sweep matches Eq. 20."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax

from compile import aot, model
from compile.kernels import ref


class TestStabilitySweep:
    def test_matches_eq20(self):
        cfg = np.array(
            [[50, 50], [200, 50], [1000, 50], [3000, 50], [10, 10], [1, 1]],
            dtype=np.float64,
        )
        (out,) = model.stability_sweep(cfg)
        out = np.asarray(out)
        for (k, l), row in zip(cfg, out):
            assert_allclose(row[0], ref.sm_tiny_stability(l, k), rtol=1e-12)
            assert row[1] == 1.0


class TestAot:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.build(str(out))
        return out, manifest

    def test_artifacts_exist_and_parse_as_hlo(self, built):
        out, manifest = built
        assert set(manifest["artifacts"]) == {"bounds", "erlang_sm", "stability"}
        for name, meta in manifest["artifacts"].items():
            path = out / meta["file"]
            text = path.read_text()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name
            # f64 end-to-end, tuple return (rust side unwraps to_tuple1).
            assert "f64[128" in text, name
            assert meta["bytes"] == len(text)

    def test_manifest_batch(self, built):
        _, manifest = built
        assert manifest["batch"] == model.BATCH == 128

    def test_entry_layout_shapes(self, built):
        out, _ = built
        text = (out / "bounds.hlo.txt").read_text()
        assert "f64[128,7]" in text
        assert "f64[128,3]" in text
        text = (out / "erlang_sm.hlo.txt").read_text()
        assert "f64[128,5]" in text

    def test_deterministic_lowering(self, built):
        out, manifest = built
        # Rebuilding yields byte-identical artifacts (reproducible AOT).
        manifest2 = aot.build(str(out))
        for name in manifest["artifacts"]:
            assert (
                manifest["artifacts"][name]["sha256_16"]
                == manifest2["artifacts"][name]["sha256_16"]
            ), name


class TestLoweredExecution:
    """Execute the jitted L2 graphs (the same computations the artifacts
    freeze) on a full batch and compare against the oracle."""

    def test_bounds_full_batch(self):
        rng = np.random.default_rng(7)
        rows = []
        for _ in range(model.BATCH):
            l = int(rng.integers(1, 40))
            k = int(rng.integers(1, 12)) * l
            rows.append([k, l, float(rng.uniform(0.1, 0.7)), k / l, 0.0, 0.0, 0.01])
        cfg = np.asarray(rows, dtype=np.float64)
        (out,) = jax.jit(model.bounds_sweep)(cfg)
        # rtol 2%: this test checks L2 lowering integrity over a broad
        # random batch. At near-stability configs the kernel (lgamma
        # identity) and oracle (masked sum) can disagree on the
        # feasibility of a single grid point by ~1 ulp, flipping the
        # argmin cell and shifting the refined optimum by up to ~2%
        # (both values are valid bounds). Exact-path equivalence on
        # interior configs is asserted at 1e-8 in the kernel tests.
        assert_allclose(np.asarray(out), ref.bounds_ref(cfg), rtol=0.02)
