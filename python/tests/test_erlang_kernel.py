"""L1 correctness: the Erlang-max (big-tasks) Pallas kernel vs the oracle
and the closed forms of Secs. 4.2-4.3."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import erlang_sm_pallas
from compile.kernels import ref


def run(rows):
    cfg = np.asarray(rows, dtype=np.float64)
    return np.asarray(erlang_sm_pallas(cfg)), ref.erlang_ref(cfg)


class TestAgainstOracle:
    def test_fig12_grid(self):
        # mu = kappa = 20 as in Fig. 12; utilization = lambda.
        rows = [[l, 20, lam, 20.0, 1e-6] for l in [1, 2, 5, 10, 50] for lam in [0.5, 0.7]]
        got, expect = run(rows)
        assert_allclose(got, expect, rtol=1e-9)


class TestClosedForms:
    def test_kappa1_harmonic_mean(self):
        # E[max_l Exp(mu)] = H_l / mu.
        for l in [1, 4, 16, 64]:
            got, _ = run([[l, 1, 0.2, 1.0, 1e-3]])
            h = ref.harmonic(l)
            assert_allclose(got[0][0], h, rtol=1e-6)
            # Eq. 23 at kappa=1 equals 1/H_l.
            assert_allclose(got[0][1], 1.0 / h, rtol=1e-6)

    def test_single_server_erlang(self):
        # l = 1: E[Delta] = kappa/mu; stability = 1.
        got, _ = run([[1, 20, 0.5, 20.0, 1e-3]])
        assert_allclose(got[0][0], 1.0, rtol=1e-7)
        assert_allclose(got[0][1], 1.0, rtol=1e-7)

    def test_stability_decreases_with_l(self):
        vals = [run([[l, 20, 0.5, 20.0, 1e-3]])[0][0][1] for l in [2, 8, 32]]
        assert vals[0] > vals[1] > vals[2]

    def test_tiny_beats_big(self):
        # Fig. 12(a): Eq. 20 (tiny) > Eq. 23 (big) for kappa = 20.
        for l in [5, 20, 50]:
            got, _ = run([[l, 20, 0.5, 20.0, 1e-3]])
            assert ref.sm_tiny_stability(l, 20 * l) > got[0][1]


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=50),
    kappa=st.integers(min_value=1, max_value=40),
    lam=st.floats(min_value=0.05, max_value=0.8),
)
def test_property_kernel_matches_oracle(l, kappa, lam):
    mu = float(kappa)  # utilization = lam
    got, expect = run([[l, kappa, lam, mu, 1e-3]])
    assert_allclose(got, expect, rtol=1e-8)
    mean_delta, rho_star, tau = got[0]
    assert mean_delta >= kappa / mu - 1e-9  # max >= single draw mean
    assert 0.0 < rho_star <= 1.0 + 1e-9
    assert tau == -1.0 or tau > 0.0
