"""L1 correctness: the envelope Pallas kernel vs the pure-numpy oracle,
plus closed-form anchors (M/M/1, Eq. 20 stability edge, paper shapes)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import bounds_pallas
from compile.kernels import ref


def run(rows):
    cfg = np.asarray(rows, dtype=np.float64)
    return np.asarray(bounds_pallas(cfg)), ref.bounds_ref(cfg)


class TestAgainstOracle:
    def test_fig8_grid(self):
        rows = []
        for k in [50, 100, 200, 400, 1000, 2500]:
            mu = k / 50.0
            rows.append([k, 50, 0.5, mu, 0.0, 0.0, 0.01])
            rows.append([k, 50, 0.5, mu, 3.1e-3, 0.02 + k * 7.4e-6, 0.01])
        got, expect = run(rows)
        assert_allclose(got, expect, rtol=1e-9)

    def test_fig13_epsilon(self):
        rows = [[k, 50, 0.5, k / 50.0, 0.0, 0.0, 1e-6] for k in [50, 200, 800, 3200]]
        got, expect = run(rows)
        assert_allclose(got, expect, rtol=1e-9)

    def test_small_systems(self):
        rows = [
            [1, 1, 0.5, 1.0, 0.0, 0.0, 0.01],
            [2, 2, 0.3, 1.0, 0.0, 0.0, 0.001],
            [8, 2, 0.3, 4.0, 1e-3, 1e-2, 0.001],
        ]
        got, expect = run(rows)
        assert_allclose(got, expect, rtol=1e-9)


class TestClosedFormAnchors:
    def test_mm1_dominates_exact(self):
        # k = l = 1: every model is an M/M/1 queue; the Chernoff bound
        # dominates the exact quantile but stays within 30%.
        lam, mu, eps = 0.5, 1.0, 0.01
        got, _ = run([[1, 1, lam, mu, 0.0, 0.0, eps]])
        exact = ref.mm1_sojourn_quantile(lam, mu, eps)
        for v in got[0]:
            assert exact <= v <= 1.3 * exact

    def test_sm_stability_edge(self):
        # l = 50, rho = 0.5: SM infeasible at small kappa, feasible at
        # kappa where Eq. 20 exceeds 0.5 (the Fig. 8(a) transition).
        for k in [50, 100]:
            got, _ = run([[k, 50, 0.5, k / 50.0, 0.0, 0.0, 0.01]])
            assert got[0][0] == -1.0, f"k={k} should be unstable"
            assert ref.sm_tiny_stability(50, k) < 0.5
        for k in [400, 1000]:
            got, _ = run([[k, 50, 0.5, k / 50.0, 0.0, 0.0, 0.01]])
            assert got[0][0] > 0.0, f"k={k} should be stable"
            assert ref.sm_tiny_stability(50, k) > 0.5

    def test_tinyfication_monotone_towards_ideal(self):
        # Paper Fig. 13: FJ bound decreases in k toward the ideal bound.
        taus = []
        ideals = []
        for k in [50, 100, 400, 1600]:
            got, _ = run([[k, 50, 0.5, k / 50.0, 0.0, 0.0, 1e-6]])
            taus.append(got[0][1])
            ideals.append(got[0][2])
        assert all(a > b for a, b in zip(taus, taus[1:]))
        # Ideal is invariant to k here (same workload distribution scaled).
        assert taus[-1] > ideals[-1]
        assert (taus[-1] - ideals[-1]) / ideals[-1] < 0.4

    def test_overhead_increases_bounds(self):
        clean, _ = run([[600, 50, 0.5, 12.0, 0.0, 0.0, 0.01]])
        dirty, _ = run([[600, 50, 0.5, 12.0, 3.1e-3, 0.0244, 0.01]])
        assert dirty[0][0] > clean[0][0]
        assert dirty[0][1] > clean[0][1]


@settings(max_examples=30, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=64),
    kappa=st.integers(min_value=1, max_value=16),
    lam=st.floats(min_value=0.05, max_value=0.9),
    eps=st.sampled_from([1e-2, 1e-4, 1e-6]),
    eo=st.floats(min_value=0.0, max_value=5e-3),
)
def test_property_kernel_matches_oracle(l, kappa, lam, eps, eo):
    """Hypothesis sweep: kernel == oracle across the parameter space, and
    outputs are either -1 (infeasible) or positive and ordered
    (ideal <= fork-join when both feasible)."""
    k = kappa * l
    mu = k / l  # E[L] = l as in the paper's sweeps
    cpd = 0.02 + k * 7.4e-6 if eo > 0 else 0.0
    got, expect = run([[k, l, lam, mu, eo, cpd, eps]])
    assert_allclose(got, expect, rtol=1e-8, atol=1e-12)
    sm, fj, ideal = got[0]
    for v in (sm, fj, ideal):
        assert v == -1.0 or v > 0.0
    if fj > 0 and ideal > 0 and eo == 0.0:
        assert ideal <= fj * (1 + 1e-9)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_batch_consistency(n, seed):
    """Batched evaluation equals row-by-row evaluation (BlockSpec
    correctness under varying batch sizes)."""
    rng = np.random.default_rng(seed)
    l = int(rng.integers(1, 32))
    rows = []
    for _ in range(n):
        k = int(rng.integers(1, 20)) * l
        rows.append([k, l, float(rng.uniform(0.1, 0.8)), k / l, 0.0, 0.0, 0.01])
    batched = np.asarray(bounds_pallas(np.asarray(rows, dtype=np.float64)))
    single = np.concatenate(
        [np.asarray(bounds_pallas(np.asarray([r], dtype=np.float64))) for r in rows]
    )
    assert_allclose(batched, single, rtol=1e-12)


def test_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        bounds_pallas(np.zeros((4, 5)))
