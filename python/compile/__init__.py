"""Build-time compile path: JAX/Pallas bound-evaluation graphs, AOT-lowered
to HLO-text artifacts loaded by the Rust coordinator. Never imported at
runtime."""
