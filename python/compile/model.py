"""Layer 2: the JAX bound-sweep graphs composed from the Layer-1 Pallas
kernels, plus the closed-form stability sweep. These are the computations
that `aot.py` lowers to HLO text for the Rust runtime.

Fixed batch shapes (AOT requires static shapes; the Rust side pads):

  bounds_sweep    : f64[BATCH, 7]  -> f64[BATCH, 3]   (envelope kernel)
  erlang_sweep    : f64[BATCH, 5]  -> f64[BATCH, 3]   (erlang-max kernel)
  stability_sweep : f64[BATCH, 2]  -> f64[BATCH, 2]   (Eq. 20 closed form)
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import bounds_pallas, erlang_sm_pallas  # noqa: E402

# Batch size baked into every artifact; Rust pads sweeps to multiples.
BATCH = 128


def bounds_sweep(configs):
    """Tiny-tasks bounds for a config batch (see kernels/envelope.py)."""
    return (bounds_pallas(configs),)


def erlang_sweep(configs):
    """Big-tasks split-merge analysis (see kernels/erlang_max.py)."""
    return (erlang_sm_pallas(configs),)


def stability_sweep(configs):
    """Closed-form stability regions.

    Input columns: 0: k, 1: l. Output columns:
      0: tiny-tasks split-merge max stable utilization (Eq. 20),
      1: fork-join max stable utilization (= 1, Sec. 3.2.2).
    The harmonic number is evaluated with a masked reciprocal sum over the
    same L_MAX grid the envelope kernel uses.
    """
    from .kernels import L_MAX

    k = configs[:, 0]
    l = configs[:, 1]
    i = 1.0 + jax.lax.broadcasted_iota(jnp.float64, (1, L_MAX), 1)
    mask = i <= l[:, None]
    harm = jnp.sum(jnp.where(mask, 1.0 / i, 0.0), axis=1)
    kappa = k / l
    sm = 1.0 / (1.0 + (harm - 1.0) / kappa)
    fj = jnp.ones_like(sm)
    return (jnp.stack([sm, fj], axis=1),)


#: name -> (callable, list of input ShapeDtypeStructs)
ARTIFACTS = {
    "bounds": (
        bounds_sweep,
        [jax.ShapeDtypeStruct((BATCH, 7), jnp.float64)],
    ),
    "erlang_sm": (
        erlang_sweep,
        [jax.ShapeDtypeStruct((BATCH, 5), jnp.float64)],
    ),
    "stability": (
        stability_sweep,
        [jax.ShapeDtypeStruct((BATCH, 2), jnp.float64)],
    ),
}
