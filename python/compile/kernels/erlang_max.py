"""Pallas kernel: big-tasks split-merge analysis (Secs. 4.2-4.3, Fig. 12).

Per configuration row (l servers, k = l big tasks ~ Erlang(kappa, mu)):

  out[0]  E[Delta] = E[max_l Erlang(kappa, mu)]          (Eq. 21)
  out[1]  max stable utilization kappa / (mu E[Delta])    (Eq. 23)
  out[2]  sojourn eps-quantile bound via the Erlang-max MGF (Sec. 4.3)
          (-1.0 when no feasible theta exists)

Config columns (f64): 0: l, 1: kappa, 2: lam, 3: mu, 4: eps.

Numerics: everything is evaluated in log space. The Erlang CCDF
``1 - F = exp(-mu y) * sum_{i<kappa} (mu y)^i / i!`` is computed as a
log-sum-exp over the masked stage grid; ``1 - F^l`` uses the
``log(-expm1(l * log1p(-ccdf)))`` identity so the MGF integrand
``(1 - F^l(y)) e^{theta y}`` never overflows even where e^{theta y}
alone would. Quadrature is composite Simpson on a fixed [QUAD] grid whose
upper limit covers the (mu - theta) decay at the largest theta on the
grid (theta <= 0.9 mu, mirrored by the Rust reference).

TPU notes: the [THETA_ERL, QUAD] f64 tile is ~4 MiB (VMEM-resident);
VPU-bound transcendentals, no MXU work.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.special import gammaln

jax.config.update("jax_enable_x64", True)

# theta grid resolution (log-spaced in (0.9*mu*1e-6, 0.9*mu]). 128 is
# sufficient because the ternary refinement recovers the continuous
# optimum from the bracketing grid cell (§Perf log: halves the [T, G]
# exp-matrix cost with no accuracy change vs the oracle).
THETA_ERL = 128
# Simpson quadrature nodes (odd => even panel count).
QUAD = 1025
# Maximum Erlang shape supported by the masked stage grid.
KAPPA_MAX = 64

ERLANG_COLS = 5
ERLANG_OUTS = 3

_NEG = -1.0


def _ln_ccdf_erlang(y, kappa, mu):
    """log of the Erlang(kappa, mu) CCDF on grid y [G] via masked LSE."""
    g = y.shape[0]
    i = jax.lax.broadcasted_iota(jnp.float64, (g, KAPPA_MAX), 1)  # [G, K]
    mask = i < kappa
    # ln term_i = i ln(mu y) - ln i!   (y = 0 handled via where)
    ln_muy = jnp.log(jnp.where(y > 0.0, mu * y, 1.0))[:, None]  # [G, 1]
    t = i * ln_muy - gammaln(i + 1.0)
    t = jnp.where(mask, t, -jnp.inf)
    tmax = jnp.max(t, axis=1, keepdims=True)  # [G, 1]
    lse = tmax[:, 0] + jnp.log(jnp.sum(jnp.exp(t - tmax), axis=1))
    ln_ccdf = -mu * y + lse
    # y = 0: CCDF = 1 exactly.
    ln_ccdf = jnp.where(y > 0.0, jnp.minimum(ln_ccdf, 0.0), 0.0)
    return ln_ccdf


def _ln_one_minus_pow(ln_ccdf, l):
    """log(1 - F^l) where F = 1 - exp(ln_ccdf), computed stably."""
    c = jnp.exp(ln_ccdf)  # CCDF in (0, 1]
    # m = l * log(F) = l * log1p(-c); c -> 1 gives m -> -inf (fine).
    m = l * jnp.log1p(-jnp.minimum(c, 1.0 - 1e-300))
    # log(1 - e^m) = log(-expm1(m)); clamp for m == 0 (c underflowed).
    em = -jnp.expm1(m)
    return jnp.log(jnp.maximum(em, 1e-300))


def _simpson_weights(g, h):
    """Composite Simpson weights on g (odd) nodes with spacing h."""
    idx = jax.lax.broadcasted_iota(jnp.float64, (g,), 0)
    w = jnp.where(idx % 2 == 1, 4.0, 2.0)
    w = w.at[0].set(1.0).at[g - 1].set(1.0)
    return w * (h / 3.0)


def _erlang_kernel(cfg_ref, out_ref):
    cfg = cfg_ref[0, :]
    l = cfg[0]
    kappa = cfg[1]
    lam = cfg[2]
    mu = cfg[3]
    eps = cfg[4]
    ln_inv_eps = -jnp.log(eps)

    # Quadrature grid: upper limit covers both the CCDF mass and the
    # slowest MGF decay (mu - theta_max = 0.1 mu).
    y_hi = (kappa + 10.0 * jnp.sqrt(kappa) + 2.0 * jnp.log(l + 1.0) + 40.0) / mu * 2.0
    h = y_hi / (QUAD - 1)
    y = jax.lax.broadcasted_iota(jnp.float64, (QUAD,), 0) * h
    w = _simpson_weights(QUAD, h)

    ln_ccdf = _ln_ccdf_erlang(y, kappa, mu)
    ln_tail = _ln_one_minus_pow(ln_ccdf, l)  # log(1 - F^l), [G]

    # --- Eq. 21: E[Delta] = int (1 - F^l) dy ---
    mean_delta = jnp.sum(w * jnp.exp(ln_tail))
    out_ref[0, 0] = mean_delta

    # --- Eq. 23: stability ---
    out_ref[0, 1] = kappa / (mu * mean_delta)

    # --- Sec. 4.3: MGF over theta grid, then Th. 1 ---
    t = jax.lax.broadcasted_iota(jnp.float64, (THETA_ERL,), 0)
    frac = t / (THETA_ERL - 1)
    sup = 0.9 * mu
    theta = (sup * 1e-6) * (0.999999e6) ** frac  # log-spaced to 0.9 mu

    ln_integrand = ln_tail[None, :] + theta[:, None] * y[None, :]  # [T, G]
    # Cap to avoid inf*0 in the weighted sum; capped entries only occur
    # where the MGF is astronomically large (infeasible theta anyway).
    ln_integrand = jnp.minimum(ln_integrand, 700.0)
    integral = jnp.sum(w[None, :] * jnp.exp(ln_integrand), axis=1)  # [T]
    mgf = 1.0 + theta * integral
    rho_s = jnp.log(mgf) / theta
    rho_a = (jnp.log(lam + theta) - jnp.log(lam)) / theta

    tau = rho_s + ln_inv_eps / theta
    feasible = rho_s <= rho_a

    # Ternary-section refinement (see envelope._grid_refine): the optimum
    # frequently sits on the feasibility boundary where tau is steep.
    def tau_fn(th):
        ln_ig = jnp.minimum(ln_tail + th * y, 700.0)
        m = 1.0 + th * jnp.sum(w * jnp.exp(ln_ig))
        rs = jnp.log(m) / th
        ra = (jnp.log(lam + th) - jnp.log(lam)) / th
        return jnp.where(rs <= ra, rs + ln_inv_eps / th, jnp.inf)

    masked = jnp.where(feasible & jnp.isfinite(tau), tau, jnp.inf)
    best = jnp.min(masked)
    idx = jnp.argmin(masked)
    a0 = theta[jnp.maximum(idx - 1, 0)]
    b0 = theta[jnp.minimum(idx + 1, THETA_ERL - 1)]

    def body(_, ab):
        a, b = ab
        m1 = a + (b - a) / 3.0
        m2 = b - (b - a) / 3.0
        take_left = tau_fn(m1) < tau_fn(m2)
        return (jnp.where(take_left, a, m1), jnp.where(take_left, m2, b))

    a, b = jax.lax.fori_loop(0, 48, body, (a0, b0))
    mid = 0.5 * (a + b)
    refined = jnp.minimum(tau_fn(mid), jnp.minimum(tau_fn(a), tau_fn(b)))
    best = jnp.minimum(best, refined)
    out_ref[0, 2] = jnp.where(jnp.isfinite(best), best, _NEG)


def erlang_sm_pallas(configs):
    """Evaluate the big-tasks kernel for a [N, ERLANG_COLS] f64 batch."""
    n = configs.shape[0]
    assert configs.shape == (n, ERLANG_COLS), configs.shape
    return pl.pallas_call(
        _erlang_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, ERLANG_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, ERLANG_OUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ERLANG_OUTS), jnp.float64),
        interpret=True,
    )(configs)
