"""Layer-1 Pallas kernels (interpret=True on CPU; see DESIGN.md
§Hardware-Adaptation for the TPU tiling story)."""

from .envelope import bounds_pallas, BOUND_COLS, BOUND_OUTS, THETA_GRID, L_MAX
from .erlang_max import erlang_sm_pallas, ERLANG_COLS, ERLANG_OUTS

__all__ = [
    "bounds_pallas",
    "erlang_sm_pallas",
    "BOUND_COLS",
    "BOUND_OUTS",
    "ERLANG_COLS",
    "ERLANG_OUTS",
    "THETA_GRID",
    "L_MAX",
]
