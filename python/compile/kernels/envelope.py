"""Pallas kernel: batched tiny-tasks quantile-bound evaluation.

For each configuration row the kernel evaluates, over a log-spaced grid of
the free MGF parameter theta, the (sigma, rho)-envelope rates of the paper

  rho_A(-theta)            Eq. 5   (Exp(lambda) arrivals)
  rho_X(theta)             Lemma 1 (masked harmonic log-sum over i <= l)
  rho_Z(theta)             Lemma 1 (Exp(l*mu) inter-start gaps)
  rho_Q(theta)             Eq. 10  (ideal partition, Erlang(k, l*mu))

and minimizes the Theorem-1 / Theorem-2 sojourn quantile expressions over
the feasible theta range, yielding per row:

  out[0]  split-merge tiny tasks   (Lemma 1 -> Th. 1; Sec. 6.2 overhead)
  out[1]  single-queue fork-join   (Th. 2;            Sec. 6.1 overhead)
  out[2]  ideal partition          (Eq. 10 -> Th. 1;  overhead ignored)

-1.0 marks an infeasible (unstable) configuration.

Config columns (all f64):
  0: k     tasks per job            4: eo    mean task overhead E[O] (Eq. 24)
  1: l     servers                  5: cpd   pre-departure overhead c_pd(k) (Eq. 3)
  2: lam   arrival rate lambda      6: eps   violation probability
  3: mu    task service rate

TPU notes (DESIGN.md #Hardware-Adaptation): after the lgamma-identity
optimization (see _log_sum_x) the working set is a handful of [THETA_GRID]
f64 vectors (~4 KiB each) -- trivially VMEM resident; the kernel is
VPU-bound (transcendentals, no MXU work), with THETA_GRID = 512 chosen as
a multiple of the 128-lane vector width. interpret=True is mandatory on
CPU (Mosaic custom-calls cannot execute on the CPU PJRT plugin).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.special import gammaln

jax.config.update("jax_enable_x64", True)

# Grid resolution: log-spaced theta in (sup*1e-6, sup), matching the Rust
# reference optimizer's coarse scan (theorem1.rs).
THETA_GRID = 512
# Maximum supported number of servers l in the masked harmonic sum.
L_MAX = 512

BOUND_COLS = 7
BOUND_OUTS = 3

_NEG = -1.0


def _theta_grid(sup):
    """Log-spaced grid in (sup*1e-6, sup*0.999999], shape [THETA_GRID]."""
    t = jax.lax.broadcasted_iota(jnp.float64, (THETA_GRID,), 0)
    frac = t / (THETA_GRID - 1)
    lo = sup * 1e-6
    hi = sup * 0.999999
    return lo * (hi / lo) ** frac


def _rho_arrival(lam, theta):
    """rho_A(-theta) = (ln(lam + theta) - ln(lam)) / theta (Eq. 5)."""
    return (jnp.log(lam + theta) - jnp.log(lam)) / theta


def _log_sum_x(l, mu, theta):
    """sum_{i=1}^{l} ln(i*mu / (i*mu - theta)) for theta < mu, elementwise
    over theta (any shape).

    Uses the exact log-gamma telescoping identity (§Perf L1 log entry —
    replaces the original [THETA_GRID, L_MAX] masked log-sum tile with
    three lgamma evaluations per theta, a ~100x FLOP reduction and the
    removal of the 2 MiB VMEM working set):

        sum ln(i mu) - sum ln(i mu - theta)
          = lnGamma(l+1) + lnGamma(1 - theta/mu) - lnGamma(l+1 - theta/mu).

    As theta -> mu, lnGamma(1 - theta/mu) -> +inf, reproducing the
    domain blow-up of the direct sum. The pure-numpy oracle (ref.py)
    keeps the naive masked sum, so the identity is independently checked
    by the kernel-vs-oracle test suite.
    """
    a = theta / mu
    return gammaln(l + 1.0) + gammaln(1.0 - a) - gammaln(l + 1.0 - a)


def _min_feasible(tau, feasible):
    """min over theta of tau where feasible, else -1."""
    masked = jnp.where(feasible & jnp.isfinite(tau), tau, jnp.inf)
    best = jnp.min(masked)
    return jnp.where(jnp.isfinite(best), best, _NEG)


# Ternary-section iterations: (2/3)^60 ≈ 3e-11 interval shrink.
REFINE_ITERS = 60


def _grid_refine(tau_fn, theta, tau_grid, feasible):
    """Grid argmin + ternary-section refinement between the neighbours.

    The optimal theta often sits on the feasibility boundary (where the
    quantile is *not* flat in theta), so a pure grid scan is 1-3% off;
    ternary section against tau_fn (which returns +inf when infeasible)
    recovers the continuous optimum. Matches the Rust reference
    optimizer's grid + golden-section structure (theorem1.rs).
    """
    masked = jnp.where(feasible & jnp.isfinite(tau_grid), tau_grid, jnp.inf)
    best = jnp.min(masked)
    idx = jnp.argmin(masked)
    t = theta.shape[0]
    a0 = theta[jnp.maximum(idx - 1, 0)]
    b0 = theta[jnp.minimum(idx + 1, t - 1)]

    def body(_, ab):
        a, b = ab
        m1 = a + (b - a) / 3.0
        m2 = b - (b - a) / 3.0
        f1 = tau_fn(m1)
        f2 = tau_fn(m2)
        take_left = f1 < f2
        return (jnp.where(take_left, a, m1), jnp.where(take_left, m2, b))

    a, b = jax.lax.fori_loop(0, REFINE_ITERS, body, (a0, b0))
    mid = 0.5 * (a + b)
    refined = jnp.minimum(tau_fn(mid), jnp.minimum(tau_fn(a), tau_fn(b)))
    out = jnp.minimum(best, refined)
    return jnp.where(jnp.isfinite(out), out, _NEG)


def _bounds_kernel(cfg_ref, out_ref):
    cfg = cfg_ref[0, :]
    k = cfg[0]
    l = cfg[1]
    lam = cfg[2]
    mu = cfg[3]
    eo = cfg[4]
    cpd = cfg[5]
    eps = cfg[6]
    ln_inv_eps = -jnp.log(eps)

    lmu = l * mu
    theta = _theta_grid(mu)  # [T], domain (0, mu) for SM/FJ

    rho_a = _rho_arrival(lam, theta)
    rho_x = _log_sum_x(l, mu, theta) / theta
    rho_z = (jnp.log(lmu) - jnp.log(lmu - theta)) / theta  # theta < mu <= lmu

    # Scalar-theta re-evaluations for the refinement stage.
    def s_rho_a(th):
        return (jnp.log(lam + th) - jnp.log(lam)) / th

    def s_rho_x(th):
        return _log_sum_x(l, mu, th) / th

    def s_rho_z(th):
        return jnp.where(th < lmu, (jnp.log(lmu) - jnp.log(lmu - th)) / th, jnp.inf)

    # --- split-merge tiny tasks (Lemma 1 + Th. 1; Sec. 6.2 overhead) ---
    # Blocking pre-departure joins the X constant (Eq. 31).
    rho_x_sm = rho_x + eo + cpd
    rho_z_o = rho_z + eo / l
    rho_s_sm = rho_x_sm + (k - l) * rho_z_o
    tau_sm = rho_s_sm + ln_inv_eps / theta

    def sm_fn(th):
        rs = s_rho_x(th) + eo + cpd + (k - l) * (s_rho_z(th) + eo / l)
        t = rs + ln_inv_eps / th
        return jnp.where(rs <= s_rho_a(th), t, jnp.inf)

    sm = _grid_refine(sm_fn, theta, tau_sm, rho_s_sm <= rho_a)

    # --- single-queue fork-join (Th. 2; Sec. 6.1 overhead) ---
    rho_x_fj = rho_x + eo
    tau_fj = (k - 1.0) * rho_z_o + rho_x_fj + ln_inv_eps / theta

    def fj_fn(th):
        rz = s_rho_z(th) + eo / l
        t = (k - 1.0) * rz + s_rho_x(th) + eo + ln_inv_eps / th
        return jnp.where(k * rz <= s_rho_a(th), t, jnp.inf)

    fj = _grid_refine(fj_fn, theta, tau_fj, k * rho_z_o <= rho_a)
    # Non-blocking pre-departure appends to the quantile (Eq. 29).
    fj = jnp.where(fj >= 0.0, fj + cpd, fj)

    # --- ideal partition (Eq. 10 + Th. 1), own grid over (0, l*mu) ---
    theta_id = theta * l
    rho_q = k * (jnp.log(lmu) - jnp.log(lmu - theta_id)) / theta_id
    rho_a_id = _rho_arrival(lam, theta_id)
    tau_id = rho_q + ln_inv_eps / theta_id

    def ideal_fn(th):
        rq = jnp.where(th < lmu, k * (jnp.log(lmu) - jnp.log(lmu - th)) / th, jnp.inf)
        t = rq + ln_inv_eps / th
        return jnp.where(rq <= s_rho_a(th), t, jnp.inf)

    ideal = _grid_refine(ideal_fn, theta_id, tau_id, rho_q <= rho_a_id)

    out_ref[0, 0] = sm
    out_ref[0, 1] = fj
    out_ref[0, 2] = ideal


def bounds_pallas(configs):
    """Evaluate the bound kernel for a [N, BOUND_COLS] f64 config batch.

    Returns [N, BOUND_OUTS] f64. One pallas grid step per config row; the
    [THETA_GRID, L_MAX] working set stays in VMEM.
    """
    n = configs.shape[0]
    assert configs.shape == (n, BOUND_COLS), configs.shape
    return pl.pallas_call(
        _bounds_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, BOUND_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BOUND_OUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, BOUND_OUTS), jnp.float64),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(configs)
