"""Pure-jnp/numpy oracle for the Pallas kernels — the correctness signal.

Implements the same math as envelope.py / erlang_max.py with plain numpy
(dense theta scan, scipy-grade quadrature) so pytest can assert_allclose
kernel outputs against an independent evaluation path.
"""

import numpy as np
from scipy.special import gammaln as _gammaln

from .envelope import BOUND_COLS, BOUND_OUTS, L_MAX, THETA_GRID
from .erlang_max import ERLANG_COLS, ERLANG_OUTS, KAPPA_MAX, QUAD, THETA_ERL


def _theta_grid(sup, n):
    frac = np.arange(n) / (n - 1)
    lo, hi = sup * 1e-6, sup * 0.999999
    return lo * (hi / lo) ** frac


def _rho_arrival(lam, theta):
    return (np.log(lam + theta) - np.log(lam)) / theta


def _rho_x(l, mu, theta):
    """(1/theta) sum_{i=1}^{l} ln(i mu / (i mu - theta)); +inf if any term
    is out of domain (theta >= mu covers all cases since i >= 1)."""
    i = np.arange(1, int(l) + 1)[None, :]
    imu = i * mu
    th = theta[:, None]
    with np.errstate(divide="ignore", invalid="ignore"):
        term = np.where(imu > th, np.log(imu) - np.log(np.maximum(imu - th, 1e-300)), np.inf)
    return term.sum(axis=1) / theta


def _min_feasible(tau, feasible):
    masked = np.where(feasible & np.isfinite(tau), tau, np.inf)
    best = masked.min()
    return best if np.isfinite(best) else -1.0


def _grid_refine(tau_fn, theta, tau_grid, feasible, iters=60):
    """Mirror of envelope._grid_refine: grid argmin + ternary section."""
    masked = np.where(feasible & np.isfinite(tau_grid), tau_grid, np.inf)
    best = masked.min()
    idx = int(masked.argmin())
    a = theta[max(idx - 1, 0)]
    b = theta[min(idx + 1, len(theta) - 1)]
    for _ in range(iters):
        m1 = a + (b - a) / 3.0
        m2 = b - (b - a) / 3.0
        if tau_fn(m1) < tau_fn(m2):
            b = m2
        else:
            a = m1
    mid = 0.5 * (a + b)
    refined = min(tau_fn(mid), tau_fn(a), tau_fn(b))
    out = min(best, refined)
    return out if np.isfinite(out) else -1.0


def bounds_ref_row(cfg):
    """Reference for one envelope-kernel config row -> [BOUND_OUTS]."""
    k, l, lam, mu, eo, cpd, eps = [float(x) for x in cfg]
    ln_inv_eps = -np.log(eps)
    theta = _theta_grid(mu, THETA_GRID)
    lmu = l * mu

    rho_a = _rho_arrival(lam, theta)
    rho_x = _rho_x(l, mu, theta)
    rho_z = (np.log(lmu) - np.log(lmu - theta)) / theta

    def s_rho_a(th):
        return (np.log(lam + th) - np.log(lam)) / th

    def s_rho_x(th):
        return float(_rho_x(l, mu, np.array([th]))[0])

    def s_rho_z(th):
        return (np.log(lmu) - np.log(lmu - th)) / th if th < lmu else np.inf

    rho_z_o = rho_z + eo / l
    rho_s_sm = rho_x + eo + cpd + (k - l) * rho_z_o

    def sm_fn(th):
        rs = s_rho_x(th) + eo + cpd + (k - l) * (s_rho_z(th) + eo / l)
        return rs + ln_inv_eps / th if rs <= s_rho_a(th) else np.inf

    sm = _grid_refine(sm_fn, theta, rho_s_sm + ln_inv_eps / theta, rho_s_sm <= rho_a)

    tau_fj = (k - 1.0) * rho_z_o + rho_x + eo + ln_inv_eps / theta

    def fj_fn(th):
        rz = s_rho_z(th) + eo / l
        t = (k - 1.0) * rz + s_rho_x(th) + eo + ln_inv_eps / th
        return t if k * rz <= s_rho_a(th) else np.inf

    fj = _grid_refine(fj_fn, theta, tau_fj, k * rho_z_o <= rho_a)
    if fj >= 0.0:
        fj += cpd

    theta_id = theta * l
    rho_q = k * (np.log(lmu) - np.log(lmu - theta_id)) / theta_id

    def ideal_fn(th):
        rq = k * (np.log(lmu) - np.log(lmu - th)) / th if th < lmu else np.inf
        return rq + ln_inv_eps / th if rq <= s_rho_a(th) else np.inf

    ideal = _grid_refine(
        ideal_fn,
        theta_id,
        rho_q + ln_inv_eps / theta_id,
        rho_q <= _rho_arrival(lam, theta_id),
    )
    return np.array([sm, fj, ideal])


def bounds_ref(configs):
    """Reference for a [N, BOUND_COLS] batch -> [N, BOUND_OUTS]."""
    configs = np.asarray(configs, dtype=np.float64)
    assert configs.shape[1] == BOUND_COLS
    return np.stack([bounds_ref_row(row) for row in configs])


# ---------------------------------------------------------------- Erlang --


def _ln_ccdf_erlang(y, kappa, mu):
    i = np.arange(KAPPA_MAX)[None, :]
    mask = i < kappa
    with np.errstate(divide="ignore"):
        ln_muy = np.where(y > 0, np.log(np.maximum(mu * y, 1e-300)), 0.0)[:, None]
    t = np.where(mask, i * ln_muy - _gammaln(i + 1.0), -np.inf)
    tmax = t.max(axis=1, keepdims=True)
    lse = tmax[:, 0] + np.log(np.exp(t - tmax).sum(axis=1))
    ln_ccdf = -mu * y + lse
    return np.where(y > 0, np.minimum(ln_ccdf, 0.0), 0.0)


def _ln_one_minus_pow(ln_ccdf, l):
    c = np.exp(ln_ccdf)
    with np.errstate(divide="ignore"):
        m = l * np.log1p(-np.minimum(c, 1 - 1e-300))
    return np.log(np.maximum(-np.expm1(m), 1e-300))


def _simpson_w(g, h):
    w = np.where(np.arange(g) % 2 == 1, 4.0, 2.0)
    w[0] = w[-1] = 1.0
    return w * h / 3.0


def erlang_ref_row(cfg):
    """Reference for one erlang-kernel config row -> [ERLANG_OUTS]."""
    l, kappa, lam, mu, eps = [float(x) for x in cfg]
    ln_inv_eps = -np.log(eps)
    y_hi = (kappa + 10.0 * np.sqrt(kappa) + 2.0 * np.log(l + 1.0) + 40.0) / mu * 2.0
    h = y_hi / (QUAD - 1)
    y = np.arange(QUAD) * h
    w = _simpson_w(QUAD, h)

    ln_tail = _ln_one_minus_pow(_ln_ccdf_erlang(y, kappa, mu), l)
    mean_delta = float((w * np.exp(ln_tail)).sum())
    rho_star = kappa / (mu * mean_delta)

    frac = np.arange(THETA_ERL) / (THETA_ERL - 1)
    sup = 0.9 * mu
    theta = (sup * 1e-6) * (0.999999e6) ** frac
    ln_integrand = np.minimum(ln_tail[None, :] + theta[:, None] * y[None, :], 700.0)
    integral = (w[None, :] * np.exp(ln_integrand)).sum(axis=1)
    mgf = 1.0 + theta * integral
    rho_s = np.log(mgf) / theta
    rho_a = _rho_arrival(lam, theta)

    def tau_fn(th):
        m = 1.0 + th * (w * np.exp(np.minimum(ln_tail + th * y, 700.0))).sum()
        rs = np.log(m) / th
        ra = (np.log(lam + th) - np.log(lam)) / th
        return rs + ln_inv_eps / th if rs <= ra else np.inf

    tau = _grid_refine(tau_fn, theta, rho_s + ln_inv_eps / theta, rho_s <= rho_a)
    return np.array([mean_delta, rho_star, tau])


def erlang_ref(configs):
    """Reference for a [N, ERLANG_COLS] batch -> [N, ERLANG_OUTS]."""
    configs = np.asarray(configs, dtype=np.float64)
    assert configs.shape[1] == ERLANG_COLS
    return np.stack([erlang_ref_row(row) for row in configs])


# ------------------------------------------------------------ closed forms


def harmonic(n):
    """H_n, exact."""
    return float(np.sum(1.0 / np.arange(1, int(n) + 1)))


def sm_tiny_stability(l, k):
    """Eq. 20."""
    kappa = k / l
    return 1.0 / (1.0 + (harmonic(l) - 1.0) / kappa)


def mm1_sojourn_quantile(lam, mu, eps):
    """Exact M/M/1 sojourn quantile: T ~ Exp(mu - lam)."""
    return -np.log(eps) / (mu - lam)
