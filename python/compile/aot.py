"""AOT lowering: JAX (L2) -> HLO **text** artifacts for the Rust runtime.

HLO text — not ``lowered.compile()`` nor serialized ``HloModuleProto`` —
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids that the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from .model import ARTIFACTS, BATCH  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-renumbering path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"batch": BATCH, "artifacts": {}}
    for name, (fn, specs) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "sha256_16": digest,
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} bytes, sha {digest})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
